"""Packaging smoke: run the save/load quickstart against an INSTALLED repro.

A file-level API redesign is exactly where packaging bit-rot hides (a new
module missing from the wheel, a src/ import that only works in a
checkout), so CI builds sdist+wheel, installs the wheel into a clean venv,
and runs this script FROM OUTSIDE the repo:

  python -m build
  python -m venv /tmp/venv && /tmp/venv/bin/pip install dist/*.whl
  cd /tmp && /tmp/venv/bin/python /path/to/tools/check_wheel.py --require-installed

``--require-installed`` fails if ``repro`` resolves to a source checkout
(src/ on the path) instead of site-packages — the guard that makes the venv
step meaningful.
"""

from __future__ import annotations

import os
import sys
import tempfile


def main() -> int:
    import numpy as np

    import repro
    from repro.api import FittedModel, SelectionPolicy

    origin = os.path.abspath(repro.__file__)
    installed = f"{os.sep}site-packages{os.sep}" in origin
    print(f"repro {repro.__version__} from {origin} (installed={installed})")
    if "--require-installed" in sys.argv and not installed:
        print("FAIL: repro imported from a source checkout, not the wheel")
        return 1

    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(70, 2)),
        rng.normal((4, 0), 0.5, size=(70, 2)),
    ]).astype(np.float32)

    model = FittedModel.fit(x, kmax=6)
    with tempfile.TemporaryDirectory() as td:
        path = model.save(os.path.join(td, "wheel-smoke.fitted.npz"))
        loaded = FittedModel.load(path)
    for mpts in loaded.mpts_values:
        np.testing.assert_array_equal(
            model.select(mpts).labels, loaded.select(mpts).labels
        )
    leaf = loaded.select(6, SelectionPolicy(method="leaf"))
    assert leaf.n_clusters >= loaded.select(6).n_clusters

    q = x[:4] + 0.02
    want = model.approximate_predict(q, mpts=6)
    got = loaded.approximate_predict(q, mpts=6)
    np.testing.assert_array_equal(want[0], got[0])
    np.testing.assert_array_equal(want[1], got[1])

    print("ok: wheel install fits, saves, loads, selects, and predicts "
          "bit-identically")
    return 0


if __name__ == "__main__":
    sys.exit(main())
