"""Cluster-parallel collectives: the clustering pipeline over sharded points.

Points live row-sharded over the mesh's ``data`` axis.  ``ring_knn`` keeps
the classic systolic structure: each shard holds its rows resident, a block
of candidate points circulates once around the ring (``ppermute``), and every
shard folds the visiting block into its running top-k.  Peak memory per shard
is O(n_local * (d + k)), never O(n^2 / P).

``ring_lune_count`` answers the RNG** lune-emptiness queries (kernels'
lune_filter semantics) against the full sharded point set: every shard tests
its local points against the (replicated) edge list and the partial verdicts
are OR-reduced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def ring_knn(xs, k: int, mesh, axis: str = "data"):
    """k nearest neighbours of each point, excluding itself.

    Args:
      xs: (n, d) points, sharded P(axis, None); n must divide the axis size.
      k: neighbours per point.
      mesh: the mesh holding ``axis``.
    Returns:
      (d2, idx): (n, k) ascending squared distances and global indices,
      sharded like the input rows.  Matches ``kernels.ops.knn`` up to f32
      reduction order.
    """
    n_shards = mesh.shape[axis]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=(P(axis, None), P(axis, None)),
        check_rep=False,
    )
    def f(x_loc):
        nl = x_loc.shape[0]
        me = jax.lax.axis_index(axis)
        rows_g = me * nl + jnp.arange(nl, dtype=jnp.int32)
        xf = x_loc.astype(jnp.float32)
        xn = jnp.sum(xf * xf, axis=-1)

        top_d = jnp.full((nl, k), jnp.inf, jnp.float32)
        top_i = jnp.full((nl, k), jnp.iinfo(jnp.int32).max, jnp.int32)
        blk = x_loc
        for t in range(n_shards):
            src = (me - t) % n_shards
            cols_g = src * nl + jnp.arange(nl, dtype=jnp.int32)
            bf = blk.astype(jnp.float32)
            bn = jnp.sum(bf * bf, axis=-1)
            d2 = xn[:, None] + bn[None, :] - 2.0 * (xf @ bf.T)
            d2 = jnp.maximum(d2, 0.0)
            d2 = jnp.where(rows_g[:, None] == cols_g[None, :], jnp.inf, d2)
            cand_d = jnp.concatenate([top_d, d2], axis=1)
            cand_i = jnp.concatenate(
                [top_i, jnp.broadcast_to(cols_g[None, :], d2.shape)], axis=1
            )
            # lexicographic (distance, index): deterministic under ties
            cand_d, cand_i = jax.lax.sort((cand_d, cand_i), dimension=1, num_keys=2)
            top_d, top_i = cand_d[:, :k], cand_i[:, :k]
            if t + 1 < n_shards:
                blk = jax.lax.ppermute(
                    blk, axis, [(i, (i + 1) % n_shards) for i in range(n_shards)]
                )
        return top_d, top_i

    return f(xs)


def ring_lune_count(xs, cd2s, ea, eb, w2, mesh, axis: str = "data"):
    """For each edge: is some point strictly inside its mrd lune?

    Args:
      xs: (n, d) points sharded P(axis, None); cd2s: (n,) squared core
      distances sharded P(axis); ea, eb, w2: (m,) replicated edge endpoints
      and squared mrd weights.
    Returns:
      (m,) bool, replicated — same verdicts as kernels.ref.lune_filter_ref
      (including its norm-scaled keep-only cancellation margin).
    """
    n_shards = mesh.shape[axis]
    m = ea.shape[0]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(), P(), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    def f(x_loc, cd2_loc, ea, eb, w2):
        nl = x_loc.shape[0]
        me = jax.lax.axis_index(axis)
        cols_g = me * nl + jnp.arange(nl, dtype=jnp.int32)

        # endpoint coordinates via one-hot gather from the sharded rows:
        # each shard contributes its resident endpoints; psum completes them.
        def gather_rows(idx):
            onehot = (idx[:, None] == cols_g[None, :]).astype(jnp.float32)
            xg = jax.lax.psum(onehot @ x_loc.astype(jnp.float32), axis)
            cg = jax.lax.psum(onehot @ cd2_loc.astype(jnp.float32), axis)
            ng = jax.lax.psum(
                onehot @ jnp.sum(x_loc.astype(jnp.float32) ** 2, -1), axis
            )
            return xg, cg, ng

        a_xyz, a_cd2, an = gather_rows(ea)
        b_xyz, b_cd2, bn = gather_rows(eb)

        xf = x_loc.astype(jnp.float32)
        cn = jnp.sum(xf * xf, axis=-1)[None, :]
        d2_ac = jnp.maximum(an[:, None] + cn - 2.0 * (a_xyz @ xf.T), 0.0)
        d2_bc = jnp.maximum(bn[:, None] + cn - 2.0 * (b_xyz @ xf.T), 0.0)
        mrd_ac = jnp.maximum(jnp.maximum(d2_ac, a_cd2[:, None]), cd2_loc[None, :])
        mrd_bc = jnp.maximum(jnp.maximum(d2_bc, b_cd2[:, None]), cd2_loc[None, :])
        eps = jnp.float32(64.0 * 1.1920929e-07)
        is_ep = (cols_g[None, :] == ea[:, None]) | (cols_g[None, :] == eb[:, None])
        inside = (
            jnp.maximum(mrd_ac + eps * (an[:, None] + cn), mrd_bc + eps * (bn[:, None] + cn))
            < w2[:, None]
        ) & ~is_ep
        return jnp.any(inside, axis=1)  # (m,) partial verdict for local points

    partial_flat = f(xs, cd2s, ea, eb, w2)  # (n_shards * m,) row-sharded
    return jnp.any(partial_flat.reshape(n_shards, m), axis=0)
