"""Core library: the paper's contribution as composable JAX modules.

Public API:
  multi_hdbscan       — all hierarchies for mpts in [kmin, kmax] via RNG^kmax
  hdbscan_baseline    — optimized re-run baseline (shared kNN + dense MST)
  fit_msts            — stage 1/2 only: shared graph + all MSTs, no extraction
  extract_hierarchies — batched on-demand extraction from a MultiMSTResult
  build_rng_graph     — the single RNG^kmax (variants rng_ss / rng_star / rng)
  boruvka_mst(_range) — batched edge-list MSTs
  linkage             — batched device single-linkage (extraction stage 1)
  hierarchy, dbcv     — extraction & validation submodules
  predict_range       — batched out-of-sample assignment over the fitted state
"""

from . import boruvka, dbcv, hierarchy, linkage, mrd, rng, sbcn, wspd
from .boruvka import boruvka_mst, boruvka_mst_range, prim_dense_mst
from .linkage import single_linkage_batch
from .mrd import core_distances2, edge_mrd2, mrd2_from_parts, reweight_all_mpts
from .multi import (
    HierarchyResult,
    LinkageRange,
    MultiDensityResult,
    MultiMSTResult,
    extract_hierarchies,
    fit_msts,
    hdbscan_baseline,
    linkage_range,
    multi_hdbscan,
)
from .rng import RngGraph, build_rng_graph

# predict consumes multi's result types; import after them (no cycle)
from . import predict
from .predict import PredictResult, membership_probabilities, predict_range

__all__ = [
    "predict", "PredictResult", "membership_probabilities", "predict_range",
    "boruvka", "dbcv", "hierarchy", "linkage", "mrd", "rng", "sbcn", "wspd",
    "boruvka_mst", "boruvka_mst_range", "prim_dense_mst", "single_linkage_batch",
    "core_distances2", "edge_mrd2", "mrd2_from_parts", "reweight_all_mpts",
    "HierarchyResult", "LinkageRange", "MultiDensityResult", "MultiMSTResult",
    "extract_hierarchies", "fit_msts", "hdbscan_baseline", "linkage_range",
    "multi_hdbscan",
    "RngGraph", "build_rng_graph",
]
