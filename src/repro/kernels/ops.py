"""Jitted public wrappers around the Pallas kernels, with backend dispatch.

Backends:
  * ``"pallas"``            — compiled Pallas (real TPU).
  * ``"pallas_interpret"``  — Pallas interpret mode (CPU correctness runs).
  * ``"jnp"``               — blocked pure-jnp fallback with the same tiling
                              structure; this is also what the CPU benchmarks
                              use (interpret mode is a Python-level emulator
                              and is not meaningful to time).
  * ``"ref"``               — the pure-jnp oracles (tests).
  * ``"mesh"``              — the multi-device ring collectives from
                              ``dist.cluster_parallel`` over a row-sharded
                              point set; requires ``mesh=`` (normally reached
                              through an ``engine.Plan``, which resolves the
                              mesh once).  Handles n not divisible by the
                              axis size via zero-padding + validity masks.

The default backend is chosen from the platform at call time.  Every kNN
backend — including ``ref`` and ``mesh`` — over-selects candidates and runs
the SAME diff-based ``_refine_knn`` pass, so near-tie neighbour ordering is
identical across backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .lune_filter import lune_filter as _lune_pallas
from .pairwise_topk import pairwise_topk as _topk_pallas


def default_backend() -> str:
    plat = jax.default_backend()
    return "pallas" if plat == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# kNN
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_top", "block_q", "block_k"))
def _knn_jnp_blocked(x, *, k_top: int, block_q: int = 1024, block_k: int = 2048):
    """Blocked jnp kNN with the same streaming top-k structure as the kernel."""
    n, d = x.shape
    block_q = min(block_q, n)
    n_pad = -(-n // block_q) * block_q
    xp = jnp.zeros((n_pad, d), x.dtype).at[:n].set(x)
    xn = jnp.sum(xp.astype(jnp.float32) ** 2, axis=-1)

    kb = min(block_k, n_pad)
    n_kb = -(-n_pad // kb)
    xkp = jnp.zeros((n_kb * kb, d), x.dtype).at[:n].set(x)
    xkn = jnp.sum(xkp.astype(jnp.float32) ** 2, axis=-1)

    def process_qblock(q0):
        q = jax.lax.dynamic_slice_in_dim(xp, q0, block_q).astype(jnp.float32)
        qn = jax.lax.dynamic_slice_in_dim(xn, q0, block_q)
        row_g = q0 + jnp.arange(block_q)

        def kv_step(carry, kb_i):
            top_d, top_i = carry
            k0 = kb_i * kb
            k = jax.lax.dynamic_slice_in_dim(xkp, k0, kb).astype(jnp.float32)
            kn = jax.lax.dynamic_slice_in_dim(xkn, k0, kb)
            d2 = qn[:, None] + kn[None, :] - 2.0 * q @ k.T
            d2 = jnp.maximum(d2, 0.0)
            col_g = k0 + jnp.arange(kb)[None, :]
            bad = (col_g == row_g[:, None]) | (col_g >= n)
            d2 = jnp.where(bad, jnp.inf, d2)
            cat_d = jnp.concatenate([top_d, d2], axis=1)
            cat_i = jnp.concatenate([top_i, jnp.broadcast_to(col_g, d2.shape)], axis=1)
            nt, at = jax.lax.top_k(-cat_d, k_top)
            return (-nt, jnp.take_along_axis(cat_i, at, axis=1)), None

        init = (
            jnp.full((block_q, k_top), jnp.inf, jnp.float32),
            jnp.full((block_q, k_top), -1, jnp.int32),
        )
        (top_d, top_i), _ = jax.lax.scan(kv_step, init, jnp.arange(n_kb))
        return top_d, top_i

    q_starts = jnp.arange(n_pad // block_q) * block_q
    top_d, top_i = jax.lax.map(process_qblock, q_starts)
    top_d = top_d.reshape(n_pad, k_top)[:n]
    top_i = top_i.reshape(n_pad, k_top)[:n]
    return top_d, top_i


@functools.partial(jax.jit, static_argnames=("k_top",))
def _refine_knn(xq, x, idx, *, k_top: int):
    """Diff-based re-evaluation of candidate distances.

    The MXU-friendly ``|q|^2+|k|^2-2qk`` form loses ~1e-3 relative accuracy to
    cancellation when point norms dwarf pair distances.  The kernels therefore
    over-select ``k_top + slack`` candidates and this pass recomputes their
    distances exactly (f32 diffs), re-sorts, and keeps the best ``k_top``.
    ``xq`` is the query set (== ``x`` for the self-kNN path; a separate batch
    for out-of-sample queries).
    """
    n = xq.shape[0]

    def chunk(args):
        xc, idx_c = args
        diff = xc[:, None, :].astype(jnp.float32) - x[idx_c].astype(jnp.float32)
        return jnp.sum(diff * diff, axis=-1)

    rows = 4096
    n_pad = -(-n // rows) * rows
    xp = jnp.zeros((n_pad,) + xq.shape[1:], xq.dtype).at[:n].set(xq)
    ip = jnp.zeros((n_pad,) + idx.shape[1:], idx.dtype).at[:n].set(idx)
    d2r = jax.lax.map(
        chunk, (xp.reshape(-1, rows, xq.shape[1]), ip.reshape(-1, rows, idx.shape[1]))
    ).reshape(n_pad, -1)[:n]
    d2r = jnp.where(idx < 0, jnp.inf, d2r)
    neg, order = jax.lax.top_k(-d2r, k_top)
    return -neg, jnp.take_along_axis(idx, order, axis=1)


def knn(
    x: jax.Array,
    k_top: int,
    *,
    backend: str | None = None,
    mesh=None,
    mesh_axis: str = "data",
    block_q: int = 256,
    block_k: int = 256,
    refine_slack: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """k nearest neighbors of each point. Returns (d2 ascending, global idx).

    All backends route their over-selected candidates through the same
    ``_refine_knn`` exact re-evaluation, so backends agree on near-tie
    neighbour ordering (the matmul-form backends lose ~1e-3 relative accuracy
    to cancellation; the ref oracle doesn't — without the shared refine the
    two can order tied neighbours differently).
    """
    backend = backend or default_backend()
    n = x.shape[0]
    k_eff = min(n - 1, k_top + refine_slack)
    if backend == "mesh":
        if mesh is None:
            raise ValueError("backend='mesh' requires mesh=")
        from ..dist import cluster_parallel as cp

        n_shards = mesh.shape[mesh_axis]
        xp = cp.shard_rows(cp.pad_rows(jnp.asarray(x), n_shards), mesh, mesh_axis)
        d2, idx = cp.ring_knn(xp, k_eff, mesh, mesh_axis, n_valid=n)
        d2, idx = d2[:n], idx[:n]
        # the exact refine pass runs replicated on the same mesh (gathers of
        # the full point set — cheap relative to the ring pass)
        x = cp.replicate(jnp.asarray(x), mesh)
    elif backend == "ref":
        d2, idx = ref.knn_ref(x, k_eff)
    elif backend == "jnp":
        d2, idx = _knn_jnp_blocked(x, k_top=k_eff)
    else:
        interpret = backend == "pallas_interpret"
        d2, idx = _topk_pallas(
            x, k_eff, block_q=block_q, block_k=block_k, interpret=interpret
        )
    return _refine_knn(x, x, idx, k_top=k_top)


def knn_from_candidates(x: jax.Array, cand_idx, *, k_top: int):
    """kNN from a precomputed host candidate matrix (the dual-tree tier).

    ``cand_idx``: (n, k_eff) int candidate neighbour ids per row (-1 pads),
    guaranteed by the producer (core.dualtree.knn_candidates) to contain
    the true ``k_top`` nearest.  Routes through the SAME ``_refine_knn``
    exact re-evaluation as every other backend, so the (d2, idx) output is
    bit-identical to the small-n tier's.
    """
    idx = jnp.asarray(np.asarray(cand_idx, np.int32))
    if idx.shape[1] < k_top:
        raise ValueError(
            f"candidate matrix has {idx.shape[1]} columns < k_top={k_top}"
        )
    return _refine_knn(x, x, idx, k_top=k_top)


# ---------------------------------------------------------------------------
# Cross-set kNN (out-of-sample queries against a fitted point set)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_top", "block_q", "block_k"))
def _query_knn_blocked(xq, x, *, k_top: int, block_q: int = 1024, block_k: int = 2048):
    """Blocked jnp cross-set kNN: rows of ``xq`` against all rows of ``x``.

    Same streaming top-k structure as ``_knn_jnp_blocked``, minus the
    self-exclusion (queries are not members of the fitted set).
    """
    q, d = xq.shape
    n = x.shape[0]
    block_q = min(block_q, q)
    q_pad = -(-q // block_q) * block_q
    qp = jnp.zeros((q_pad, d), xq.dtype).at[:q].set(xq)
    qn = jnp.sum(qp.astype(jnp.float32) ** 2, axis=-1)

    kb = min(block_k, n)
    n_kb = -(-n // kb)
    xkp = jnp.zeros((n_kb * kb, d), x.dtype).at[:n].set(x)
    xkn = jnp.sum(xkp.astype(jnp.float32) ** 2, axis=-1)

    def process_qblock(q0):
        qb = jax.lax.dynamic_slice_in_dim(qp, q0, block_q).astype(jnp.float32)
        qbn = jax.lax.dynamic_slice_in_dim(qn, q0, block_q)

        def kv_step(carry, kb_i):
            top_d, top_i = carry
            k0 = kb_i * kb
            k = jax.lax.dynamic_slice_in_dim(xkp, k0, kb).astype(jnp.float32)
            kn = jax.lax.dynamic_slice_in_dim(xkn, k0, kb)
            d2 = qbn[:, None] + kn[None, :] - 2.0 * qb @ k.T
            d2 = jnp.maximum(d2, 0.0)
            col_g = k0 + jnp.arange(kb)[None, :]
            d2 = jnp.where(col_g >= n, jnp.inf, d2)
            cat_d = jnp.concatenate([top_d, d2], axis=1)
            cat_i = jnp.concatenate(
                [top_i, jnp.broadcast_to(col_g, d2.shape)], axis=1
            )
            nt, at = jax.lax.top_k(-cat_d, k_top)
            return (-nt, jnp.take_along_axis(cat_i, at, axis=1)), None

        init = (
            jnp.full((block_q, k_top), jnp.inf, jnp.float32),
            jnp.full((block_q, k_top), -1, jnp.int32),
        )
        (top_d, top_i), _ = jax.lax.scan(kv_step, init, jnp.arange(n_kb))
        return top_d, top_i

    q_starts = jnp.arange(q_pad // block_q) * block_q
    top_d, top_i = jax.lax.map(process_qblock, q_starts)
    return top_d.reshape(q_pad, k_top)[:q], top_i.reshape(q_pad, k_top)[:q]


@functools.partial(jax.jit, static_argnames=("k_top",))
def _query_knn_ref(xq, x, *, k_top: int):
    """Exact cross-set kNN oracle: full (q, n) matrix + top_k."""
    d2 = ref.pairwise_d2_ref(xq, x)
    neg, idx = jax.lax.top_k(-d2, k_top)
    return -neg, idx


def query_knn(
    xq: jax.Array,
    x: jax.Array,
    k_top: int,
    *,
    backend: str | None = None,
    refine_slack: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """k nearest *fitted* neighbors of each query row.  (d2 ascending, idx).

    The out-of-sample twin of ``knn``: queries in ``xq`` are ranked against
    the fitted set ``x`` (no self-exclusion — queries are not fitted points).
    Every backend routes its over-selected candidates through the same
    ``_refine_knn`` exact re-evaluation as the self-kNN path, so prediction
    is bit-identical across ``ref``/``jnp``/``pallas*`` backends.  The
    Pallas backends use the blocked jnp program: the cross-set pass is a
    (q, n) sweep with q << n, far off the self-kNN kernel's hot path.
    """
    backend = backend or default_backend()
    n = x.shape[0]
    if k_top > n:
        raise ValueError(f"k_top={k_top} must be <= n={n} fitted points")
    if xq.shape[0] == 0:
        raise ValueError("query set is empty (callers handle q=0 upstream)")
    k_eff = min(n, k_top + refine_slack)
    if backend == "ref":
        d2, idx = _query_knn_ref(xq, x, k_top=k_eff)
    else:
        d2, idx = _query_knn_blocked(xq, x, k_top=k_eff)
    return _refine_knn(xq, x, idx, k_top=k_top)


# ---------------------------------------------------------------------------
# Lune filter
# ---------------------------------------------------------------------------


@jax.jit
def _lune_jnp(edges_a, edges_b, w2, points, cd2):
    """Blocked jnp exact lune check. edges_*: (m,) int32 indices into points."""
    a_xyz = points[edges_a]
    b_xyz = points[edges_b]
    a_cd2 = cd2[edges_a]
    b_cd2 = cd2[edges_b]

    m = edges_a.shape[0]
    block = 4096

    # Simple chunked map over edges to bound the (m, n) intermediate.
    n_chunks = -(-m // block)
    m_pad = n_chunks * block
    pad = lambda v: jnp.concatenate([v, jnp.zeros((m_pad - m,) + v.shape[1:], v.dtype)])  # noqa: E731
    aX, bX, aC, bC = pad(a_xyz), pad(b_xyz), pad(a_cd2), pad(b_cd2)
    aI = pad(edges_a)
    bI = pad(edges_b)
    # padded edges: w2 = -inf -> never removed
    W = jnp.concatenate([w2, jnp.full((m_pad - m,), -jnp.inf, w2.dtype)])

    def chunk(i):
        s = lambda v: jax.lax.dynamic_slice_in_dim(v, i * block, block)  # noqa: E731
        return ref.lune_filter_ref(s(aX), s(bX), s(aC), s(bC), s(aI), s(bI), s(W), points, cd2)

    out = jax.lax.map(chunk, jnp.arange(n_chunks))
    return out.reshape(m_pad)[:m]


def lune_nonempty(
    edges_a: jax.Array,
    edges_b: jax.Array,
    w2: jax.Array,
    points: jax.Array,
    cd2: jax.Array,
    *,
    backend: str | None = None,
    mesh=None,
    mesh_axis: str = "data",
    block_e: int = 256,
    block_c: int = 512,
) -> jax.Array:
    """(m,) bool — True where lune(a,b) contains a point strictly inside."""
    backend = backend or default_backend()
    # pow2-pad the edge axis so the compiled program is keyed by scale
    # bucket, not by the exact (dataset-dependent) unresolved-edge count;
    # padded edges have w2 = -inf => nothing is ever inside their lune
    m = edges_a.shape[0]
    m_pad = 1 << max(0, int(m - 1).bit_length())
    if m_pad != m and backend != "mesh" and m > 0:
        zpad = jnp.zeros((m_pad - m,), jnp.int32)
        edges_a = jnp.concatenate([jnp.asarray(edges_a, jnp.int32), zpad])
        edges_b = jnp.concatenate([jnp.asarray(edges_b, jnp.int32), zpad])
        w2 = jnp.concatenate(
            [jnp.asarray(w2, jnp.float32),
             jnp.full((m_pad - m,), -jnp.inf, jnp.float32)]
        )
        return lune_nonempty(
            edges_a, edges_b, w2, points, cd2,
            backend=backend, mesh=mesh, mesh_axis=mesh_axis,
            block_e=block_e, block_c=block_c,
        )[:m]
    if backend == "mesh":
        if mesh is None:
            raise ValueError("backend='mesh' requires mesh=")
        from ..dist import cluster_parallel as cp

        n = points.shape[0]
        n_shards = mesh.shape[mesh_axis]
        xp = cp.shard_rows(cp.pad_rows(jnp.asarray(points), n_shards), mesh, mesh_axis)
        cp2 = cp.shard_rows(cp.pad_rows(jnp.asarray(cd2), n_shards), mesh, mesh_axis)
        return cp.ring_lune_count(
            xp,
            cp2,
            cp.replicate(jnp.asarray(edges_a, jnp.int32), mesh),
            cp.replicate(jnp.asarray(edges_b, jnp.int32), mesh),
            cp.replicate(jnp.asarray(w2), mesh),
            mesh,
            mesh_axis,
            n_valid=n,
        )
    if backend in ("jnp", "ref"):
        return _lune_jnp(edges_a, edges_b, w2, points, cd2)
    interpret = backend == "pallas_interpret"
    return _lune_pallas(
        points[edges_a],
        points[edges_b],
        cd2[edges_a],
        cd2[edges_b],
        edges_a,
        edges_b,
        w2,
        points,
        cd2,
        block_e=block_e,
        block_c=block_c,
        interpret=interpret,
    )
