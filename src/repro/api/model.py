"""`FittedModel`: the fitted multi-hierarchy state as a first-class artifact.

The paper's pitch is fit-once/query-many: ONE shared graph answers every
mpts in the range.  This module makes that fitted state portable — an
immutable artifact holding the data, the multi-MST result, and a lazily
materialized ``LinkageRange`` — with:

  * ``FittedModel.fit(X, kmax=...)``       — the one device-heavy step;
  * ``model.select(mpts, policy)``         — a :class:`Clustering` query view
    (labels, probabilities, condensed tree, exemplars) under any
    :class:`~repro.api.selection.SelectionPolicy`, LRU-cached per
    (mpts, policy);
  * ``model.select_all(policy)``           — every fitted density level from
    one batched device linkage pass;
  * ``model.approximate_predict(Q, ...)``  — out-of-sample assignment, no
    refit;
  * ``model.save(path)`` / ``FittedModel.load(path)`` — the artifact layer:
    one ``.npz`` (arrays + a JSON header carrying schema version, config
    fingerprint + hash, and git/backend/dtype provenance) so fit happens
    once and any number of serve workers boot from disk in milliseconds.
    ``load`` rejects schema-version and config mismatches with a usable
    message instead of serving silently wrong answers.

``repro.api.MultiHDBSCAN`` wraps this class with the sklearn-style
surface; ``repro.serve.ClusterServeEngine`` serves it under traffic.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import json
import os
import tempfile
from typing import Sequence

import numpy as np

from .. import engine
from ..core import dbcv as dbcv_mod
from ..core import multi, predict
from .selection import SelectionPolicy

ARTIFACT_SCHEMA_VERSION = 1
_ARTIFACT_FORMAT = "repro.fitted_model"


class ArtifactError(RuntimeError):
    """A FittedModel artifact could not be read: corrupted file, wrong or
    missing header, schema-version mismatch, or config mismatch."""


def _config_hash(config: dict) -> str:
    return hashlib.sha256(
        json.dumps(config, sort_keys=True).encode()
    ).hexdigest()[:16]


def _git_sha() -> str:
    """HEAD sha of the repo that CONTAINS this package, else "unknown".

    A pip-installed repro can live inside some other project's git work
    tree (project-local venv); recording that repo's HEAD as repro
    provenance would be authoritative-looking nonsense, so the sha is only
    trusted when the resolved work tree actually holds the package.
    """
    import subprocess

    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    if f"{os.sep}site-packages{os.sep}" in pkg_dir:
        return "unknown"
    try:
        top = subprocess.run(
            ["git", "-C", pkg_dir, "rev-parse", "--show-toplevel"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        if not top or not pkg_dir.startswith(os.path.abspath(top) + os.sep):
            return "unknown"
        out = subprocess.run(
            ["git", "-C", pkg_dir, "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
        )
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _exemplars(h: multi.HierarchyResult) -> list[np.ndarray]:
    """Most-persistent point ids per selected cluster (hdbscan-style).

    For each selected cluster, take the leaf clusters of its condensed
    subtree and, within each leaf, the points that survive to the leaf's
    deepest departure lambda — the density peaks the cluster is "about".
    """
    tree = h.condensed
    n = tree.n_points
    cluster_rows = tree.child >= n
    kids: dict[int, list[int]] = {}
    for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows]):
        kids.setdefault(int(p), []).append(int(c))
    pt_parent = tree.parent[~cluster_rows]
    pt_child = tree.child[~cluster_rows]
    pt_lam = tree.lam[~cluster_rows]

    out: list[np.ndarray] = []
    for c in sorted(h.selected):
        leaves: list[int] = []
        stack = [int(c)]
        while stack:
            v = stack.pop()
            ch = kids.get(v)
            if ch:
                stack.extend(ch)
            else:
                leaves.append(v)
        picks = []
        for leaf in leaves:
            rows = pt_parent == leaf
            if rows.any():
                lam = pt_lam[rows]
                finite = np.isfinite(lam)
                cap = lam[finite].max() if finite.any() else lam.max()
                picks.append(pt_child[rows][lam >= cap])
        out.append(
            np.sort(np.concatenate(picks)) if picks else np.empty(0, np.int64)
        )
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class Clustering:
    """One density level under one selection policy: a cheap query view.

    Holds the extracted hierarchy plus lazily computed per-point views; the
    underlying arrays are shared with the model's cache, so constructing a
    Clustering never re-extracts.  Identity semantics (``eq=False``): the
    numpy-bearing hierarchy makes field-wise ==/hash ill-defined.
    """

    mpts: int
    policy: SelectionPolicy
    hierarchy: multi.HierarchyResult = dataclasses.field(repr=False)

    @property
    def labels(self) -> np.ndarray:
        """(n,) int64 cluster labels of the fitted points; -1 = noise."""
        return self.hierarchy.labels

    @property
    def n_clusters(self) -> int:
        return self.hierarchy.n_clusters

    @property
    def lambdas(self) -> np.ndarray:
        """(n,) departure lambda of each fitted point (0 for noise)."""
        return np.asarray(self.hierarchy.point_lambda)

    @property
    def condensed_tree(self):
        return self.hierarchy.condensed

    @property
    def stability(self) -> dict[int, float]:
        return self.hierarchy.stability

    @property
    def selected(self) -> list[int]:
        """Selected condensed-cluster ids (sorted order = label order)."""
        return self.hierarchy.selected

    @functools.cached_property
    def probabilities(self) -> np.ndarray:
        """(n,) hdbscan-style membership strength in [0, 1] (0 = noise)."""
        return predict.membership_probabilities(self.hierarchy)

    @functools.cached_property
    def exemplars(self) -> list[np.ndarray]:
        """Per-label arrays of the most-persistent point ids (density peaks)."""
        return _exemplars(self.hierarchy)

    def __repr__(self) -> str:
        return (
            f"Clustering(mpts={self.mpts}, n_clusters={self.n_clusters}, "
            f"policy={self.policy.describe()!r})"
        )


class FittedModel:
    """Immutable fitted artifact: one graph, all hierarchies, cheap views.

    Build with :meth:`fit` (device-heavy, once) or :meth:`load` (from a
    saved artifact, milliseconds).  Everything query-side — ``select``,
    ``select_all``, ``approximate_predict``, the profiles — extracts lazily
    from the resident state and caches per (mpts, policy).

    The fitted arrays (``X``, ``msts``) are treated as immutable; the only
    mutable state is the extraction cache, bounded by
    ``max_cached_hierarchies`` (LRU) for long-lived serving processes.
    """

    def __init__(
        self,
        *,
        X: np.ndarray,
        msts: multi.MultiMSTResult,
        policy: SelectionPolicy,
        plan: "engine.Plan",
        config: dict,
        provenance: dict | None = None,
        max_cached_hierarchies: int | None = None,
    ):
        self.X = X
        self.msts = msts
        self.default_policy = policy
        self.plan = plan
        self.config = config
        self.provenance = provenance or {}
        self.max_cached_hierarchies = max_cached_hierarchies
        self._linkage: multi.LinkageRange | None = None
        self._cache: collections.OrderedDict[
            tuple[int, SelectionPolicy], multi.HierarchyResult
        ] = collections.OrderedDict()
        self._walk: dict[SelectionPolicy, dict[int, predict.WalkTable]] = {}

    # -- construction ------------------------------------------------------

    @classmethod
    def fit(
        cls,
        X,
        kmax: int = 16,
        *,
        kmin: int = 2,
        mpts_values: Sequence[int] | None = None,
        policy: SelectionPolicy | None = None,
        variant: str = "rng_star",
        backend: str | None = None,
        mesh=None,
        plan: "engine.Plan | str" = "auto",
        max_cached_hierarchies: int | None = None,
    ) -> "FittedModel":
        """One fit buys the whole mpts range (no extraction happens here)."""
        X = np.asarray(X)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-d (n_samples, n_features); got {X.shape}")
        if kmax < 2:
            raise ValueError(f"kmax must be >= 2; got {kmax}")
        if X.shape[0] <= kmax:
            raise ValueError(
                f"n_samples must exceed kmax; got n={X.shape[0]}, kmax={kmax}"
            )
        if not (np.issubdtype(X.dtype, np.number) or X.dtype == np.bool_):
            raise ValueError(f"X must be numeric; got dtype {X.dtype}")
        # NaN/inf would otherwise flow unchecked into the host WSPD
        # fair-split tree (poisoning bbox splits) and the f32 tie-epsilon
        # machinery (NaN never compares, silently dropping candidates) —
        # reject here with a usable message.
        bad = ~np.isfinite(X)
        if bad.any():
            rows = np.flatnonzero(bad.any(axis=1))
            raise ValueError(
                f"X contains {int(bad.sum())} non-finite value(s) "
                f"(NaN or inf) in {len(rows)} row(s), first at row "
                f"{int(rows[0])}; clean or impute before fit()"
            )
        policy = policy if policy is not None else SelectionPolicy()
        resolved = engine.resolve_plan(plan, backend=backend, mesh=mesh)
        msts = multi.fit_msts(
            X, kmax, kmin=kmin, variant=variant,
            mpts_values=mpts_values, plan=resolved,
        )
        config = {
            "n": int(X.shape[0]),
            "d": int(X.shape[1]),
            "x_dtype": str(X.dtype),
            "kmax": int(kmax),
            "kmin": int(kmin),
            "mpts_values": [int(m) for m in msts.mpts_values],
            "variant": variant,
        }
        return cls(
            X=X,
            msts=msts,
            policy=policy,
            plan=resolved,
            config=config,
            provenance=cls._fresh_provenance(resolved, X),
            max_cached_hierarchies=max_cached_hierarchies,
        )

    @staticmethod
    def _fresh_provenance(plan: "engine.Plan", X: np.ndarray) -> dict:
        import jax

        from .. import __version__

        return {
            "repro_version": __version__,
            "git_sha": _git_sha(),
            "jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "platform": jax.default_backend(),
            "backend": plan.backend,
            "plan": plan.describe(),
            "x_dtype": str(X.dtype),
        }

    # -- cheap metadata ----------------------------------------------------

    @property
    def n_samples(self) -> int:
        return self.msts.n

    @property
    def n_features(self) -> int:
        return int(self.X.shape[1])

    @property
    def kmax(self) -> int:
        return self.msts.kmax

    @property
    def mpts_values(self) -> list[int]:
        return list(self.msts.mpts_values)

    @property
    def config_hash(self) -> str:
        """16-hex fingerprint of the workload config (n/d/dtype/range/variant)."""
        return _config_hash(self.config)

    @property
    def graph(self):
        """The fitted RNG^kmax (RngGraph: edges, d2, variant, stats)."""
        return self.msts.graph

    @property
    def n_graph_edges(self) -> int:
        return len(self.msts.graph.edges)

    def row_of(self, mpts: int) -> int:
        """Index of ``mpts`` in the fitted range (KeyError outside it)."""
        return self.msts.row_of(mpts)

    # -- query views -------------------------------------------------------

    def _resolve_policy(self, policy: SelectionPolicy | None) -> SelectionPolicy:
        return self.default_policy if policy is None else policy

    def _ensure_linkage(self) -> multi.LinkageRange:
        """All dendrograms for the range in ONE device program, on first need."""
        if self._linkage is None:
            self._linkage = multi.linkage_range(self.msts)
        return self._linkage

    def hierarchy(
        self, mpts: int, policy: SelectionPolicy | None = None
    ) -> multi.HierarchyResult:
        """Condensed tree / stabilities / labels at one level (LRU-cached).

        The cache key is (mpts, policy): selection is per-query state, so
        e.g. a serve engine answering mixed eom/leaf traffic holds both
        views without re-extraction — bounded by ``max_cached_hierarchies``.
        """
        row = self.msts.row_of(mpts)
        pol = self._resolve_policy(policy)
        key = (mpts, pol)
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        h = multi.extract_one_from_linkage(
            self.msts, self._ensure_linkage(), row, policy=pol
        )
        self._cache[key] = h
        bound = self.max_cached_hierarchies
        while bound is not None and len(self._cache) > bound:
            (em, ep), _ = self._cache.popitem(last=False)
            self._walk.get(ep, {}).pop(em, None)
        return h

    def select(self, mpts: int, policy: SelectionPolicy | None = None) -> Clustering:
        """The clustering at one density level under one selection policy."""
        pol = self._resolve_policy(policy)
        return Clustering(mpts=mpts, policy=pol, hierarchy=self.hierarchy(mpts, pol))

    def select_all(self, policy: SelectionPolicy | None = None) -> list[Clustering]:
        """Every fitted density level, from one batched device linkage pass."""
        self._ensure_linkage()
        return [self.select(m, policy) for m in self.msts.mpts_values]

    def mst(self, mpts: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(ea, eb, w) MST edges under mutual reachability at this mpts."""
        row = self.msts.row_of(mpts)
        return self.msts.mst_ea[row], self.msts.mst_eb[row], self.msts.mst_w[row]

    # -- out-of-sample prediction ------------------------------------------

    def _walk_cache(self, policy: SelectionPolicy) -> dict[int, predict.WalkTable]:
        return self._walk.setdefault(policy, {})

    def predict_range(
        self,
        Q,
        *,
        mpts_values: Sequence[int] | None = None,
        policy: SelectionPolicy | None = None,
    ) -> predict.PredictResult:
        """Out-of-sample assignment for the requested mpts rows (one pass)."""
        pol = self._resolve_policy(policy)
        Q = np.asarray(Q)
        predict.validate_queries(Q, self.n_features)
        return predict.predict_range(
            self.msts,
            self.X,
            Q,
            lambda m: self.hierarchy(m, pol),
            plan=self.plan,
            mpts_values=mpts_values,
            table_cache=self._walk_cache(pol),
        )

    def approximate_predict(
        self, Q, mpts: int | None = None, policy: SelectionPolicy | None = None
    ):
        """hdbscan-style ``approximate_predict`` over the fitted state.

        With ``mpts`` given: ``(labels, probabilities)`` for that level;
        with ``mpts=None``: the full per-mpts
        :class:`~repro.core.predict.PredictResult`.
        """
        res = self.predict_range(
            Q, mpts_values=None if mpts is None else [mpts], policy=policy
        )
        if mpts is None:
            return res
        return res.labels[0], res.probabilities[0]

    # -- range-level profiles ----------------------------------------------

    def mpts_profile(self, policy: SelectionPolicy | None = None) -> list[dict]:
        """One summary row per density level (the paper's exploration query)."""
        rows = []
        for mpts in self.msts.mpts_values:
            h = self.hierarchy(mpts, policy)
            sizes = np.bincount(h.labels[h.labels >= 0], minlength=h.n_clusters)
            selected_stab = sorted(
                (h.stability.get(c, 0.0) for c in h.selected), reverse=True
            )
            rows.append({
                "mpts": mpts,
                "n_clusters": h.n_clusters,
                "n_noise": int((h.labels == -1).sum()),
                "cluster_sizes": sizes.tolist(),
                "max_stability": float(selected_stab[0]) if selected_stab else 0.0,
                "total_stability": float(sum(selected_stab)),
            })
        return rows

    def dbcv_profile(self, policy: SelectionPolicy | None = None) -> list[dict]:
        """DBCV relative validity at every fitted density level."""
        rows = []
        for mpts in self.msts.mpts_values:
            h = self.hierarchy(mpts, policy)
            rows.append({
                "mpts": mpts,
                "dbcv": dbcv_mod.dbcv_relative_validity(
                    h.mst_ea, h.mst_eb, h.mst_w, h.labels
                ),
                "n_clusters": h.n_clusters,
            })
        return rows

    # -- artifact layer ----------------------------------------------------

    def save(self, path: str) -> str:
        """Write the fitted state as one ``.npz`` artifact (atomic replace).

        Layout: every fitted array flat in the npz, plus a ``__header__``
        entry — UTF-8 JSON carrying the format tag, schema version, config
        fingerprint + hash, default selection policy, and provenance
        (repro/jax versions, git sha, backend/platform/dtype).  Returns
        ``path``.
        """
        arrays, msts_meta = multi.pack_msts(self.msts)
        header = {
            "format": _ARTIFACT_FORMAT,
            "schema_version": ARTIFACT_SCHEMA_VERSION,
            "config": self.config,
            "config_hash": self.config_hash,
            "policy": self.default_policy.to_dict(),
            "provenance": self.provenance,
            "msts_meta": msts_meta,
        }
        header_bytes = np.frombuffer(
            json.dumps(header, sort_keys=True).encode("utf-8"), dtype=np.uint8
        )
        dirname = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, __header__=header_bytes, X=self.X, **arrays)
            os.replace(tmp, path)  # a loader never sees a half-written file
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    @classmethod
    def load(
        cls,
        path: str,
        *,
        backend: str | None = None,
        mesh=None,
        plan: "engine.Plan | str" = "auto",
        policy: SelectionPolicy | None = None,
        max_cached_hierarchies: int | None = None,
        expect_config_hash: str | None = None,
    ) -> "FittedModel":
        """Boot a FittedModel from a saved artifact — no refit, milliseconds.

        Execution placement is resolved fresh against THIS host (``backend``
        defaults to the platform's auto-selection, not the saving host's),
        so an artifact fitted on a TPU pod serves from a CPU laptop.  Pass
        ``expect_config_hash`` to pin the workload a deployment expects;
        any mismatch — like a corrupted file or a schema-version gap — is
        an :class:`ArtifactError` with a message naming the problem.
        """
        try:
            with np.load(path, allow_pickle=False) as z:
                files = set(z.files)
                if "__header__" not in files:
                    raise ArtifactError(
                        f"{path}: no __header__ entry — not a FittedModel artifact"
                    )
                try:
                    header = json.loads(z["__header__"].tobytes().decode("utf-8"))
                except (ValueError, UnicodeDecodeError) as e:
                    raise ArtifactError(
                        f"{path}: corrupted artifact header ({e})"
                    ) from e
                cls._check_header(path, header, expect_config_hash)
                missing = {"X"} - files
                if missing:
                    raise ArtifactError(
                        f"{path}: artifact is missing arrays {sorted(missing)}"
                    )
                X = z["X"]
                arrays = {k: z[k] for k in files if k not in ("__header__", "X")}
        except ArtifactError:
            raise
        except Exception as e:  # unreadable zip, truncated entries, OSError
            raise ArtifactError(
                f"{path}: not a readable FittedModel artifact "
                f"({type(e).__name__}: {e})"
            ) from e

        try:
            msts = multi.unpack_msts(arrays, header["msts_meta"])
        except KeyError as e:
            raise ArtifactError(f"{path}: artifact is missing arrays [{e}]") from e
        config = header["config"]
        cls._check_consistency(path, config, X, msts)

        pol = policy if policy is not None else SelectionPolicy.from_dict(
            header.get("policy", {})
        )
        resolved = engine.resolve_plan(plan, backend=backend, mesh=mesh)
        return cls(
            X=X,
            msts=msts,
            policy=pol,
            plan=resolved,
            config=config,
            provenance=header.get("provenance", {}),
            max_cached_hierarchies=max_cached_hierarchies,
        )

    @staticmethod
    def _check_header(path, header, expect_config_hash):
        if header.get("format") != _ARTIFACT_FORMAT:
            raise ArtifactError(
                f"{path}: header format {header.get('format')!r} is not "
                f"{_ARTIFACT_FORMAT!r} — not a FittedModel artifact"
            )
        version = header.get("schema_version")
        if version != ARTIFACT_SCHEMA_VERSION:
            raise ArtifactError(
                f"{path}: artifact schema version {version} but this build "
                f"reads version {ARTIFACT_SCHEMA_VERSION}; re-save the model "
                f"with a matching repro build"
            )
        config = header.get("config")
        if not isinstance(config, dict) or "config_hash" not in header:
            raise ArtifactError(f"{path}: artifact header has no config fingerprint")
        actual = _config_hash(config)
        if actual != header["config_hash"]:
            raise ArtifactError(
                f"{path}: config fingerprint mismatch (header says "
                f"{header['config_hash']}, config hashes to {actual}) — the "
                f"artifact was corrupted or hand-edited; refit and re-save"
            )
        if expect_config_hash is not None and actual != expect_config_hash:
            raise ArtifactError(
                f"{path}: artifact config hash {actual} does not match the "
                f"expected {expect_config_hash} (different dataset, kmax, "
                f"range, or variant than this deployment was built for)"
            )

    @staticmethod
    def _check_consistency(path, config, X, msts):
        problems = []
        if tuple(X.shape) != (config.get("n"), config.get("d")):
            problems.append(
                f"X shape {tuple(X.shape)} != config (n, d)="
                f"({config.get('n')}, {config.get('d')})"
            )
        if msts.kmax != config.get("kmax"):
            problems.append(f"msts kmax {msts.kmax} != config kmax {config.get('kmax')}")
        if msts.cd2.shape != (msts.n, msts.kmax):
            problems.append(
                f"cd2 shape {msts.cd2.shape} != (n, kmax)=({msts.n}, {msts.kmax})"
            )
        if list(msts.mpts_values) != list(config.get("mpts_values", [])):
            problems.append("stored mpts rows disagree with the config range")
        if msts.mst_ea.shape != (len(msts.mpts_values), msts.n - 1):
            problems.append(
                f"MST row array shape {msts.mst_ea.shape} != "
                f"(R, n-1)=({len(msts.mpts_values)}, {msts.n - 1})"
            )
        if problems:
            raise ArtifactError(
                f"{path}: artifact arrays disagree with its config "
                f"fingerprint ({'; '.join(problems)}) — corrupted or "
                f"mixed-up artifact; refit and re-save"
            )

    def __repr__(self) -> str:
        return (
            f"FittedModel(n={self.n_samples}, d={self.n_features}, "
            f"kmax={self.kmax}, R={len(self.msts.mpts_values)}, "
            f"policy={self.default_policy.describe()!r}, "
            f"config_hash={self.config_hash}, plan={self.plan.describe()})"
        )
