"""RNG construction pipeline: RNG** -> RNG* -> exact RNG (paper §IV-E, Alg. 1).

Variants (paper's naming):
  * ``rng_ss``  (RNG**): WSPD+SBCN supergraph, no filtering (Alg. 1 line 12).
  * ``rng_star`` (RNG*): + the 2*kmax-check filter using each endpoint's
    kmax-NN list, plus the core-distance certificate for definite keeps
    (lines 13-21).  May keep some non-RNG edges.
  * ``rng``     (exact): + full-dataset lune scan for edges the cheap filter
    could not certify either way (lines 22-26) — the Pallas ``lune_filter``
    kernel / its jnp twin / the mesh ring collective, per plan.

All predicates run in squared space (see core.mrd).

Two device data-planes build the filtered graph:

  * the FUSED CASCADE (default): bounded per-row candidate emission
    (``sbcn.cascade_candidates`` — packed int32 keys, single-key dedup
    sort), then the staged ``plan.edge_cascade`` programs: a ``stage1_k``
    lune prefilter kills ~90% of candidates before the full kmax-list
    check + core-distance certificate run on the survivors.  Staging and
    bounded emission are exact (see kernels.fused_cascade / core.sbcn);
    when emission detects a per-row tie overflow (mass-duplicate inputs)
    the build transparently falls back to
  * the SLOT-ARRAY path (``sbcn_candidates`` + ``filter_cascade_device``):
    dense per-cell slots, scatter compaction, unstaged kNN-lune check.
    Retained as the golden reference (tests pin the fused path's edge sets
    against it), as the ``backend="ref"`` path, and for n too large to pack
    (lo, hi) into int32 keys.

Dataflow: the WSPD tree and pair recursion are host control-plane (numpy);
everything else is device-resident jax programs over padded/masked arrays.
Host syncs are the named ledger points only: ``candidate_count`` /
``stage1_count`` (scalars sizing the static compactions), ``graph`` (the
one bulk materialization), and ``lune_exact`` for the exact variant.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .. import engine
from . import dualtree as dualtree_mod
from . import mrd as mrd_mod
from . import sbcn as sbcn_mod
from . import wspd as wspd_mod

VARIANTS = ("rng_ss", "rng_star", "rng")

# the fused path packs (lo, hi) as lo * n + hi into int32 keys
_PACK_LIMIT = 46340


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


@dataclasses.dataclass
class RngGraph:
    """The single precomputed graph that serves the whole mpts range."""

    edges: np.ndarray      # (m, 2) int64, a < b
    d2: np.ndarray         # (m,)  squared Euclidean edge lengths
    w2_kmax: np.ndarray    # (m,)  squared mrd_kmax weights
    variant: str
    n_points: int
    stats: dict


@functools.partial(jax.jit, static_argnames=("chunk",))
def _knn_lune_check(x, cd2k, knn_idx, knn_d2, ea, eb, w2, *, chunk: int = 16384):
    """Paper lines 14-17: is any kmax-NN of a or b strictly inside lune(a,b)?

    Tie robustness: mrd ties are STRUCTURAL here (e.g. c is b's kmax-th
    neighbor => mrd(b,c) = cd(b) = mrd(a,b) exactly in real arithmetic), and
    f32 noise — including XLA's per-callsite FMA contraction, which makes
    even identical formulas differ by ulps across call sites — must never
    flip a tie into a removal.  Two defenses: (1) own-list distances are read
    from the stored kNN pass instead of recomputed, making the most common
    tie bit-exact; (2) a norm-scaled epsilon margin is added on the "inside"
    side, so residual noise can only KEEP an edge (the superset-safe
    direction), mirroring the exact-filter kernel.

    Returns (m,) bool `inside_any`.
    """
    eps = jnp.float32(64.0 * 1.1920929e-07)

    def one_chunk(args):
        ea_c, eb_c, w2_c = args
        cand_a = knn_idx[ea_c]                                           # (c, k)
        cand_b = knn_idx[eb_c]
        xa = x[ea_c].astype(jnp.float32)
        xb = x[eb_c].astype(jnp.float32)
        xca = x[cand_a].astype(jnp.float32)                              # (c, k, d)
        xcb = x[cand_b].astype(jnp.float32)
        # own-list distances come from storage; cross distances are recomputed
        d2a_ca = knn_d2[ea_c]                                            # d2(a, cand_a)
        d2b_cb = knn_d2[eb_c]                                            # d2(b, cand_b)
        d2b_ca = jnp.sum((xb[:, None, :] - xca) ** 2, -1)                # d2(b, cand_a)
        d2a_cb = jnp.sum((xa[:, None, :] - xcb) ** 2, -1)                # d2(a, cand_b)

        cda = cd2k[ea_c][:, None]
        cdb = cd2k[eb_c][:, None]
        an = jnp.sum(xa * xa, -1)[:, None]
        bn = jnp.sum(xb * xb, -1)[:, None]

        def inside(cand, xc, d2ac, d2bc):
            cdc = cd2k[cand]
            cn = jnp.sum(xc * xc, -1)
            mrd_ac = jnp.maximum(jnp.maximum(d2ac, cda), cdc) + eps * (an + cn)
            mrd_bc = jnp.maximum(jnp.maximum(d2bc, cdb), cdc) + eps * (bn + cn)
            not_ep = (cand != ea_c[:, None]) & (cand != eb_c[:, None])
            return jnp.any(
                (jnp.maximum(mrd_ac, mrd_bc) < w2_c[:, None]) & not_ep, axis=1
            )

        return inside(cand_a, xca, d2a_ca, d2b_ca) | inside(cand_b, xcb, d2a_cb, d2b_cb)

    m = ea.shape[0]
    m_pad = -(-m // chunk) * chunk
    pad = lambda v, f: jnp.concatenate(  # noqa: E731
        [v, jnp.full((m_pad - m,), f, v.dtype)]
    )
    ea_p, eb_p = pad(ea, 0), pad(eb, 0)
    w2_p = pad(w2, -jnp.inf)  # padded edges can never have points inside
    res = jax.lax.map(
        one_chunk,
        (
            ea_p.reshape(-1, chunk),
            eb_p.reshape(-1, chunk),
            w2_p.reshape(-1, chunk),
        ),
    )
    return res.reshape(m_pad)[:m]


def filter_cascade_device(
    x: jax.Array,
    cd2: jax.Array,
    knn_idx: jax.Array,
    knn_d2: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    valid: jax.Array,
    *,
    plan: engine.Plan,
):
    """Device filter cascade over padded/masked candidate slots.

    Returns device arrays ``(keep, certified, inside_any, d2_e, w2)`` — keep
    is the RNG* verdict (valid & not removed by the kNN-lune check);
    certified marks edges provably in the exact RNG (w == max core dist).
    Nothing is materialized; invalid slots read index 0 and are masked.
    """
    cd2k = cd2[:, -1]
    ea = jnp.where(valid, lo, 0).astype(jnp.int32)
    eb = jnp.where(valid, hi, 0).astype(jnp.int32)
    d2_e = mrd_mod.edge_d2(x, ea, eb)
    w2 = mrd_mod.mrd2_from_parts(d2_e, cd2k[ea], cd2k[eb])
    inside_any = _knn_lune_check(
        x, cd2k, knn_idx, knn_d2, ea, eb, w2, chunk=plan.filter_chunk
    ) & valid
    # core-distance certificate: w == max(c(a), c(b))  =>  definitely in RNG
    certified = (w2 == jnp.maximum(cd2k[ea], cd2k[eb])) & valid
    keep = valid & ~inside_any
    return keep, certified, inside_any, d2_e, w2


def _exact_lune_pass(keep, certified, ea_h, eb_h, w2_h, x, cd2k, plan, stats):
    """variant="rng" (Alg. 1 lines 22-26): exact lune scan of the edges the
    cheap filter could not certify either way.  Mutates ``stats``; returns
    the updated keep mask (host bool array)."""
    unresolved = keep & ~certified
    stats["m_unresolved"] = int(unresolved.sum())
    if not unresolved.any():
        return keep
    keep = keep.copy()  # device_get views are read-only
    ui = np.nonzero(unresolved)[0]
    nonempty = engine.to_host(
        plan.lune_nonempty(
            jnp.asarray(ea_h[ui], jnp.int32),
            jnp.asarray(eb_h[ui], jnp.int32),
            jnp.asarray(w2_h[ui]),
            x,
            cd2k,
        ),
        "lune_exact",
    )
    keep[ui[nonempty]] = False
    stats["m_removed_exact"] = int(nonempty.sum())
    return keep


def filter_edges(
    x: jax.Array,
    cd2: jax.Array,
    knn_idx: jax.Array,
    knn_d2: jax.Array,
    edges: np.ndarray,
    variant: str,
    *,
    backend: str | None = None,
    plan: engine.Plan | None = None,
) -> tuple[np.ndarray, dict]:
    """Apply the paper's filter cascade to an explicit (m, 2) edge array.

    Compatibility wrapper over ``filter_cascade_device`` for host edge lists;
    returns (kept edge array, stats dict).
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    plan = engine.resolve_plan(plan, backend=backend) if not isinstance(plan, engine.Plan) else plan
    stats = {"m_candidates": int(len(edges))}
    if variant == "rng_ss" or len(edges) == 0:
        return edges, stats

    lo = jnp.asarray(edges[:, 0], jnp.int32)
    hi = jnp.asarray(edges[:, 1], jnp.int32)
    valid = jnp.ones((len(edges),), bool)
    keep_d, certified_d, inside_d, _, w2_d = filter_cascade_device(
        x, cd2, knn_idx, knn_d2, lo, hi, valid, plan=plan
    )
    keep, certified, inside_any, w2 = engine.to_host(
        (keep_d, certified_d, inside_d, w2_d), "graph"
    )
    stats["m_removed_knn"] = int(inside_any.sum())
    stats["m_certified"] = int((keep & certified).sum())

    if variant == "rng":
        keep = _exact_lune_pass(
            keep, certified, edges[:, 0], edges[:, 1], w2, x, cd2[:, -1], plan, stats
        )
    return edges[keep], stats


def _empty_graph(variant: str, n: int, n_pairs: int) -> RngGraph:
    return RngGraph(
        edges=np.zeros((0, 2), np.int64),
        d2=np.zeros((0,), np.float32),
        w2_kmax=np.zeros((0,), np.float32),
        variant=variant,
        n_points=n,
        stats={"m_candidates": 0, "n_wspd_pairs": n_pairs, "m_edges": 0},
    )


@jax.jit
def _unpack_keys(ks, n_pack):
    """Sorted packed keys -> (valid, first-occurrence, lo, hi)."""
    valid = ks != sbcn_mod._SENTINEL
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    safe = jnp.where(valid, ks, 0)
    return valid, first, (safe // n_pack).astype(jnp.int32), (safe % n_pack).astype(jnp.int32)


@jax.jit
def _split_survivors(valid, first, killed1, cert1):
    """Stage-1 verdicts -> (certified survivors, open survivors, counts)."""
    surv = valid & first & ~killed1
    sc = surv & cert1
    so = surv & ~cert1
    return sc, so, jnp.sum(sc), jnp.sum(so)


@functools.partial(jax.jit, static_argnames=("cap",))
def _compact_idx(mask, *, cap: int):
    return jnp.nonzero(mask, size=cap, fill_value=0)[0]


def _build_fused(
    x, cd2, knn_d2, knn_idx, tree, pu, pv, variant, plan
) -> RngGraph | None:
    """Fused-cascade RNG build; returns None on tie overflow (caller falls
    back to the slot-array path, which keeps ALL tied SBCN minima)."""
    n = x.shape[0]
    cd2k = cd2[:, -1]
    keys_sorted, n_real_d, n_unique_d, n_mutual_d, n_overflow_d = (
        sbcn_mod.cascade_candidates(
            x,
            cd2k,
            tree.perm,
            tree.start[pu],
            tree.end[pu] - tree.start[pu],
            tree.start[pv],
            tree.end[pv] - tree.start[pv],
            tie_cap=plan.cascade_tie_cap,
            tier_chunk_elems=plan.tier_chunk_elems,
        )
    )
    # ONE scalar sync sizes the stage-1 buffer and reports the exact tie
    # overflow verdict (no silent edge drops — overflow means fall back)
    n_real, n_unique, n_mutual, n_overflow = (
        int(v)
        for v in engine.to_host(
            (n_real_d, n_unique_d, n_mutual_d, n_overflow_d), "candidate_count"
        )
    )
    if n_overflow:
        return None
    if n_real == 0:
        return _empty_graph(variant, n, int(len(pu)))

    cap = min(_pow2_ceil(n_real), keys_sorted.shape[0])
    ks = keys_sorted[:cap]
    n_pack = jnp.int32(n)

    stats = {
        "m_candidates": n_unique,
        "n_wspd_pairs": int(len(pu)),
        "m_candidate_slots": n_real,
        "m_mutual_slots": n_mutual,
        "path": "fused",
    }

    # stage 1: cheap prefilter over each endpoint's k1 nearest — its
    # removals are a subset of the full check's, so survivors-only stage 2
    # gives the identical final verdict for a fraction of the work.  The
    # core-distance certificate splits the survivors further: a certified
    # edge has w == max(cd(a), cd(b)) and every lune competitor c satisfies
    # mrd(a,c) >= cd(a) and mrd(b,c) >= cd(b) (max is exact in f32), so
    # nothing can ever lie strictly inside its lune — certified survivors
    # skip the full check entirely.
    k_full = knn_idx.shape[1]
    k1 = min(plan.cascade_stage1_k, k_full)
    if plan.backend in ("pallas", "pallas_interpret"):
        valid, first, lo, hi = _unpack_keys(ks, n_pack)
        killed1, cert1, d2_1, w2_1 = plan.edge_cascade(
            x, cd2k, knn_idx, knn_d2, lo, hi, valid, k_check=k1
        )
        surv_cert, surv_open, nc_d, no_d = _split_survivors(
            valid, first, killed1, cert1
        )
    else:
        # jnp backends: the whole stage-1 block is one program
        from ..kernels import fused_cascade as fc

        lo, hi, d2_1, w2_1, surv_cert, surv_open, nc_d, no_d = fc.stage1_packed(
            x, cd2k, knn_idx, knn_d2, ks, n_pack,
            k_check=k1, chunk=plan.cascade_chunk,
        )
    n_cert, n_open = (
        int(v) for v in engine.to_host((nc_d, no_d), "stage1_count")
    )
    if n_cert + n_open == 0:
        return _empty_graph(variant, n, int(len(pu)))

    q = 8192  # survivor caps quantized to coarse blocks: few programs, <12% pad
    parts_dev = []
    if n_cert:
        capc = min(_pow2_ceil(n_cert), -(-n_cert // q) * q)
        posc = _compact_idx(surv_cert, cap=capc)
        validc = jnp.arange(capc) < n_cert
        keepc = validc  # certified => provably in the exact RNG
        d2c, w2c = canonical_edge_weights(x, cd2k, lo[posc], hi[posc])
        parts_dev.append(
            (lo[posc], hi[posc], keepc, validc, d2c, w2c, w2_1[posc])
        )
    if n_open:
        capo = min(_pow2_ceil(n_open), -(-n_open // q) * q)
        poso = _compact_idx(surv_open, cap=capo)
        valido = jnp.arange(capo) < n_open
        killed2, _, d2_2, w2_2 = plan.edge_cascade(
            x, cd2k, knn_idx, knn_d2, lo[poso], hi[poso], valido,
            k_check=k_full,
        )
        d2o, w2o = canonical_edge_weights(x, cd2k, lo[poso], hi[poso])
        parts_dev.append(
            (lo[poso], hi[poso], valido & ~killed2, jnp.zeros_like(valido),
             d2o, w2o, w2_2)
        )

    parts = engine.to_host(parts_dev, "graph")
    lo_h = np.concatenate([p[0] for p in parts])
    hi_h = np.concatenate([p[1] for p in parts])
    keep = np.concatenate([p[2] for p in parts])
    certified = np.concatenate([p[3] for p in parts])
    d2_h = np.concatenate([p[4] for p in parts])
    w2_h = np.concatenate([p[5] for p in parts])
    w2_stage = np.concatenate([p[6] for p in parts])
    # restore the slot path's sorted-(lo, hi) edge order: downstream MST
    # tie-breaks are by edge id, so order parity keeps the paths bit-equal
    order = np.lexsort((hi_h, lo_h))
    lo_h, hi_h, keep, certified, d2_h, w2_h, w2_stage = (
        v[order] for v in (lo_h, hi_h, keep, certified, d2_h, w2_h, w2_stage)
    )
    stats["m_removed_knn"] = n_unique - int(keep.sum())
    stats["m_certified"] = int((keep & certified).sum())

    if variant == "rng":
        # the lune pass thresholds on the STAGE w2 values (their verdicts
        # carry the eps margins); the exported arrays stay canonical
        keep = _exact_lune_pass(
            keep, certified, lo_h, hi_h, w2_stage, x, cd2k, plan, stats
        )

    edges = np.stack(
        [lo_h[keep].astype(np.int64), hi_h[keep].astype(np.int64)], axis=1
    )
    stats["m_edges"] = int(len(edges))
    return RngGraph(
        edges=edges,
        d2=d2_h[keep],
        w2_kmax=w2_h[keep],
        variant=variant,
        n_points=n,
        stats=stats,
    )


# ---------------------------------------------------------------------------
# Canonical per-edge weight program
# ---------------------------------------------------------------------------
#
# The d2/w2_kmax arrays EXPORTED on RngGraph feed the all-mpts reweight and
# therefore every MST weight downstream.  XLA codegen is only bitwise
# deterministic within one compiled program: the same diff-form formula
# inlined into the fused-cascade programs, the eager slot-path ops and a
# separate jitted helper can disagree by ulps (per-callsite FMA contraction,
# shape-dependent vectorization), which breaks the bit-parity contract
# between candidate paths that produce the same edge set.  So every path
# exports through THIS one program: a fixed (chunk,)-shaped lax.map body,
# shared via the cached_program registry — identical program, identical
# operand shapes, identical bits, for any edge count.  The filter stages
# keep using their own in-program values (their verdicts carry eps margins
# that absorb ulp noise); only the exported arrays are canonicalized.

_WEIGHT_CHUNK = 4096


@functools.partial(jax.jit, static_argnames=("chunk",))
def _edge_weights_chunked(x, cd2k, ea, eb, *, chunk: int):
    def one(args):
        ea_c, eb_c = args
        d2 = mrd_mod.edge_d2(x, ea_c, eb_c)
        return d2, mrd_mod.mrd2_from_parts(d2, cd2k[ea_c], cd2k[eb_c])

    d2, w2 = jax.lax.map(
        one, (ea.reshape(-1, chunk), eb.reshape(-1, chunk))
    )
    return d2.reshape(-1), w2.reshape(-1)


def canonical_edge_weights(x, cd2k, ea, eb):
    """Exact f32 (d2, w2_kmax) for an edge list — the one export program.

    Pads to the fixed chunk multiple (index-0 edges, sliced back off), so
    the compiled body sees one shape regardless of m and two calls on the
    same (n, d) dataset agree bitwise edge-for-edge.
    """
    m = int(ea.shape[0])
    m_pad = -(-max(m, 1) // _WEIGHT_CHUNK) * _WEIGHT_CHUNK
    ea = jnp.asarray(ea, jnp.int32)
    eb = jnp.asarray(eb, jnp.int32)
    if m_pad != m:
        pad = jnp.zeros((m_pad - m,), jnp.int32)
        ea = jnp.concatenate([ea, pad])
        eb = jnp.concatenate([eb, pad])
    prog = engine.plan.cached_program(
        ("edge_weights_canonical", _WEIGHT_CHUNK, int(x.shape[1])),
        lambda: functools.partial(_edge_weights_chunked, chunk=_WEIGHT_CHUNK),
    )
    d2, w2 = prog(x, cd2k, ea, eb)
    return d2[:m], w2[:m]


def _build_dualtree(
    x, knn_d2, knn_idx, variant, plan, x_host, knn_d2_host, knn_idx_host
) -> RngGraph:
    """Large-n tier: dual-tree Borůvka candidate edges + device weights.

    The host traversals select edge STRUCTURE only (core.dualtree); the d2
    and w2_kmax values that reach results come from the canonical per-edge
    weight program every tier exports through, in one ``graph`` sync.  The
    graph is kNN^kmax ∪ S with S ⊇ an MST under mrd_kmax — a strict
    superset of what every per-mpts MST needs (see core.dualtree), though
    NOT an RNG: the ``variant`` filter semantics don't apply on this tier.
    """
    n = x.shape[0]
    edges, stats = dualtree_mod.candidate_edges(
        x_host,
        knn_d2_host,
        knn_idx_host,
        leaf_size=plan.dualtree_leaf,
        margin=plan.dualtree_margin,
    )
    stats["path"] = "dualtree"
    m = len(edges)
    stats["m_edges"] = m
    if m == 0:
        return _empty_graph(variant, n, 0)
    d2_d, w2_d = canonical_edge_weights(
        x,
        knn_d2[:, -1],
        jnp.asarray(edges[:, 0], jnp.int32),
        jnp.asarray(edges[:, 1], jnp.int32),
    )
    d2_h, w2_h = engine.to_host((d2_d, w2_d), "graph")
    return RngGraph(
        edges=edges,
        d2=d2_h,
        w2_kmax=w2_h,
        variant=variant,
        n_points=n,
        stats=stats,
    )


def build_rng_graph(
    x: jax.Array,
    knn_d2: jax.Array,
    knn_idx: jax.Array,
    *,
    variant: str = "rng_star",
    separation: float = 1.0,
    backend: str | None = None,
    plan: engine.Plan | None = None,
    x_host: np.ndarray | None = None,
    cd_kmax_host: np.ndarray | None = None,
    knn_d2_host: np.ndarray | None = None,
    knn_idx_host: np.ndarray | None = None,
) -> RngGraph:
    """End-to-end candidate graph construction (Alg. 1 lines 5-29).

    knn_d2/knn_idx: the single (kmax-1)-NN pass (ascending squared distances).
    ``x_host`` / ``cd_kmax_host`` / ``knn_*_host`` feed the host control
    planes without a device sync when the caller already holds host views
    (fit_msts does); left None they are materialized here under the
    ``input`` tag.

    Size-tier dispatch (``plan.use_dualtree``): large n routes to the
    dual-tree Borůvka candidate path (``core.dualtree``, stats
    ``path="dualtree"``); otherwise the WSPD/SBCN build below runs —
    fused cascade by default, slot-array path as fallback/oracle.
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    plan = plan if isinstance(plan, engine.Plan) else engine.resolve_plan(plan, backend=backend)
    n = x.shape[0]
    cd2 = mrd_mod.core_distances2(knn_d2)
    if x_host is None:
        x_host = engine.io.ensure_host(x)

    if n > 2 and plan.use_dualtree(int(n)):
        if knn_d2_host is None or knn_idx_host is None:
            knn_d2_host, knn_idx_host = (
                engine.io.ensure_host(knn_d2),
                engine.io.ensure_host(knn_idx),
            )
        return _build_dualtree(
            x, knn_d2, knn_idx, variant, plan, x_host, knn_d2_host, knn_idx_host
        )

    if cd_kmax_host is None:
        cd_kmax_host = np.sqrt(
            engine.io.ensure_host(cd2[:, -1]).astype(np.float64)
        )

    # -- host control plane: fair-split tree + well-separated pairs ---------
    tree = wspd_mod.build_fair_split_tree(
        np.asarray(x_host, np.float64), cd_kmax_host
    )
    pu, pv = wspd_mod.wspd_pairs(tree, s=separation)

    # -- fused cascade (default): bounded emission + staged fused filter ----
    if variant != "rng_ss" and plan.backend != "ref" and n <= _PACK_LIMIT:
        g = _build_fused(x, cd2, knn_d2, knn_idx, tree, pu, pv, variant, plan)
        if g is not None:
            return g
        # per-row tie overflow (mass duplicates): the slot path below keeps
        # every tied SBCN minimum, so no candidate is lost

    # -- slot-array data plane: dense candidates + unstaged filter cascade --
    lo_s, hi_s, keep_s = sbcn_mod.sbcn_candidates(
        x,
        cd2[:, -1],
        tree.perm,
        tree.start[pu],
        tree.end[pu] - tree.start[pu],
        tree.start[pv],
        tree.end[pv] - tree.start[pv],
        tile_elems=plan.sbcn_tile_elems,
        pair_cap=plan.sbcn_pair_cap,
        row_chunk=plan.sbcn_row_chunk,
    )
    # Compact the sparse candidate slots to ~m edges ON DEVICE.  The filter
    # cascade must run on the unique candidates, not the (much larger) slot
    # array; the only thing that crosses to the host here is the COUNT — one
    # int — which sizes the static nonzero buffer.
    m_cand = int(engine.to_host(jnp.sum(keep_s), "candidate_count"))
    if m_cand == 0:
        return RngGraph(
            edges=np.zeros((0, 2), np.int64),
            d2=np.zeros((0,), np.float32),
            w2_kmax=np.zeros((0,), np.float32),
            variant=variant,
            n_points=n,
            stats={"m_candidates": 0, "n_wspd_pairs": int(len(pu)), "m_edges": 0},
        )
    cap = -(-m_cand // 4096) * 4096  # quantized: reuses filter programs
    pos = jnp.nonzero(keep_s, size=cap, fill_value=0)[0]
    lo = lo_s[pos]
    hi = hi_s[pos]
    valid = jnp.arange(cap) < m_cand

    cd2k = cd2[:, -1]
    ea = jnp.where(valid, lo, 0).astype(jnp.int32)
    eb = jnp.where(valid, hi, 0).astype(jnp.int32)
    if variant == "rng_ss":
        keep_d = valid
        certified_d = inside_d = jnp.zeros_like(valid)
        w2_d = jnp.zeros((int(valid.shape[0]),), jnp.float32)
    else:
        keep_d, certified_d, inside_d, _, w2_d = filter_cascade_device(
            x, cd2, knn_idx, knn_d2, lo, hi, valid, plan=plan
        )
    # exported weights always come from the canonical program (the filter
    # verdicts above keep their own in-program values)
    d2c_d, w2c_d = canonical_edge_weights(x, cd2k, ea, eb)

    # -- the one graph materialization --------------------------------------
    lo_h, hi_h, valid_h, keep, certified, inside_any, d2_h, w2_h, w2_stage = (
        engine.to_host(
            (lo, hi, valid, keep_d, certified_d, inside_d, d2c_d, w2c_d, w2_d),
            "graph",
        )
    )
    stats = {
        "m_candidates": int(valid_h.sum()),
        "n_wspd_pairs": int(len(pu)),
    }
    if variant != "rng_ss":
        stats["m_removed_knn"] = int(inside_any.sum())
        stats["m_certified"] = int((keep & certified).sum())

    if variant == "rng":
        keep = _exact_lune_pass(
            keep, certified, lo_h, hi_h, w2_stage, x, cd2[:, -1], plan, stats
        )

    edges = np.stack(
        [lo_h[keep].astype(np.int64), hi_h[keep].astype(np.int64)], axis=1
    )
    stats["m_edges"] = int(len(edges))
    return RngGraph(
        edges=edges,
        d2=d2_h[keep],
        w2_kmax=w2_h[keep],
        variant=variant,
        n_points=n,
        stats=stats,
    )
