"""Core distances and mutual-reachability distances (paper §III-B).

Everything internal is kept in *squared* space: ``max`` and all comparisons
commute with ``sqrt`` for non-negative values, so lune tests, SBCN argmins and
MST structure are identical whether run on ``d`` or ``d^2`` — and squared
space saves the sqrt and is numerically cleaner on bf16/f32 inputs.

Convention (matches the paper): the ``mpts``-NN of ``p`` *includes p itself*,
so ``c_1(p) = 0`` and ``c_j(p)`` = distance to its (j-1)-th nearest *other*
point.  A single (kmax-1)-NN pass therefore yields every core distance
``c_j, j in [1, kmax]`` — Algorithm 1 lines 1-3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def core_distances2(knn_d2: jax.Array) -> jax.Array:
    """(n, kmax-1) ascending squared kNN distances -> (n, kmax) squared core dists.

    Column ``j-1`` holds ``c_j^2``; column 0 is identically 0 (mpts=1).
    """
    n = knn_d2.shape[0]
    return jnp.concatenate([jnp.zeros((n, 1), knn_d2.dtype), knn_d2], axis=1)


def mrd2_from_parts(d2: jax.Array, cd2_a: jax.Array, cd2_b: jax.Array) -> jax.Array:
    """Squared mutual reachability: max(d^2, c(a)^2, c(b)^2) (Eq. 1, squared)."""
    return jnp.maximum(jnp.maximum(cd2_a, cd2_b), d2)


def edge_d2(x: jax.Array, ea: jax.Array, eb: jax.Array) -> jax.Array:
    """Squared Euclidean distance for an explicit edge list."""
    diff = x[ea].astype(jnp.float32) - x[eb].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def edge_mrd2(
    x: jax.Array, cd2_col: jax.Array, ea: jax.Array, eb: jax.Array
) -> jax.Array:
    """Squared mrd for edges under ONE mpts value (cd2_col = cd2[:, mpts-1])."""
    return mrd2_from_parts(edge_d2(x, ea, eb), cd2_col[ea], cd2_col[eb])


def reweight_all_mpts(d2_e: jax.Array, cd2: jax.Array, ea: jax.Array, eb: jax.Array) -> jax.Array:
    """Edge weights for EVERY mpts in the range at once.

    Args:
      d2_e: (m,) squared Euclidean edge lengths.
      cd2:  (n, kmax) squared core distances (col j-1 = c_j^2).
    Returns:
      (kmax, m) squared mrd weights; row j-1 corresponds to mpts=j.

    This is the "re-compute its edge weights instead of the edge weights of
    the complete graph" step (§IV), batched over the whole mpts range — the
    TPU adaptation vmaps the range rather than looping it.
    """
    return jnp.maximum(jnp.maximum(cd2[ea].T, cd2[eb].T), d2_e[None, :])
