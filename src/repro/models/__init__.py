"""Model zoo registry: cfg.arch -> module with a uniform surface.

Every arch module exposes:
  init(cfg, key) -> (params, specs)
  forward(p, cfg, tokens|dec_tokens, <frontend input>) -> (hidden, aux_loss)
  logits_fn(p, cfg, hidden) -> logits
  init_cache(cfg, batch, max_len, [dtype]) -> cache pytree
  prefill(p, cfg, <inputs>, max_len) -> (last_logits, cache)
  decode_step(p, cfg, cache, cur_tokens) -> (logits, cache)
"""

import jax
import jax.numpy as jnp

from . import encdec, griffin, layers, ssm, transformer
from .transformer import abstract_init as _abstract_init_raw


def _cast_params(cfg, params):
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, params
    )


def init_params(cfg, key):
    """init + master-dtype cast (cfg.param_dtype)."""
    mod = get_model(cfg)
    params, specs = mod.init(cfg, key)
    return _cast_params(cfg, params), specs


def abstract_init(cfg):
    """(ShapeDtypeStruct params in master dtype, specs) with zero allocation."""
    mod = get_model(cfg)
    shapes, specs = _abstract_init_raw(mod.init, cfg)
    dt = jnp.dtype(cfg.param_dtype)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, dt)
        if x.dtype == jnp.float32
        else x,
        shapes,
    )
    return shapes, specs

_REGISTRY = {
    "transformer": transformer,
    "mamba2": ssm,
    "griffin": griffin,
    "encdec": encdec,
}


def get_model(cfg):
    return _REGISTRY[cfg.arch]


__all__ = ["encdec", "griffin", "layers", "ssm", "transformer", "get_model", "abstract_init", "init_params"]
