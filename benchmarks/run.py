"""Benchmark entry point: one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Full-scale sweeps live in
paper_sweeps.py; this entry runs host-sized versions of each (the paper's
headline quantities — speedup ratios and edge-count reductions — are
scale-free).  Roofline rows are appended from the dry-run artifacts when
present (derived = dominant-term milliseconds).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import paper_sweeps

    rows = []
    print("name,us_per_call,derived")

    # Fig 5a/6a: dataset-size sweep
    for r in paper_sweeps.size_sweep(sizes=(1000, 2000, 4000), d=8, kmax=16):
        name = f"fig5a_size/n={r['n']}/{r['method']}"
        edge_red = r["edges_complete"] / max(r["edges"], 1)
        print(f"{name},{r['wall_s'] * 1e6:.0f},edge_reduction={edge_red:.1f}x")
        rows.append(r)

    # Fig 5b/6b: dimensionality sweep
    for r in paper_sweeps.dim_sweep(dims=(2, 8, 32), n=2000, kmax=16):
        name = f"fig5b_dims/d={r['d']}/{r['method']}"
        edge_red = r["edges_complete"] / max(r["edges"], 1)
        print(f"{name},{r['wall_s'] * 1e6:.0f},edge_reduction={edge_red:.1f}x")
        rows.append(r)

    # Fig 5c/6c + Table II + Fig 7: kmax sweep with ratio-vs-one-hierarchy
    for r in paper_sweeps.kmax_sweep(kmaxes=(4, 16, 64), n=2000, d=8):
        name = f"tab2_kmax/k={r['kmax']}/{r['method']}"
        print(f"{name},{r['wall_s'] * 1e6:.0f},ratio_vs_one={r['ratio_vs_one']}")
        rows.append(r)

    # extraction phase: batched device linkage vs legacy per-edge Python loop
    for r in paper_sweeps.extraction_sweep(n=2000, d=8, kmax=16):
        name = f"extract/k={r['kmax']}/{r['method']}"
        print(f"{name},{r['wall_s'] * 1e6:.0f},speedup_vs_loop={r['speedup_vs_loop']}x")
        rows.append(r)

    # roofline rows from dry-run artifacts (if the matrix has been run)
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art):
        from benchmarks import roofline

        recs = roofline.load_records(art)
        for r in recs:
            if r.get("status") != "ok" or r.get("mesh") != "single":
                continue
            t = r["roofline"]
            dom_ms = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"]) * 1e3
            print(
                f"roofline/{r['arch']}/{r['shape']},{r['t_compile_s'] * 1e6:.0f},"
                f"dominant={t['dominant']}:{dom_ms:.1f}ms"
            )

    import json

    out = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench_rows.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)

    pipeline = pipeline_bench()
    print(
        f"pipeline/multi,{pipeline['multi']['total'] * 1e6:.0f},"
        f"speedup_vs_baseline={pipeline['speedup_vs_baseline']}x"
    )
    srv = pipeline["serve"]
    print(
        f"serve/predict,{srv['p50_ms'] * 1e3:.0f},"
        f"p95_ms={srv['p95_ms']};qps={srv['queries_per_s']}"
    )
    art = pipeline["artifact"]
    print(
        f"artifact/save_load,{art['save_ms'] * 1e3:.0f},"
        f"load_ms={art['load_ms']};bytes={art['bytes']}"
    )
    nscale = nscale_sweep()
    pipeline["nscale"] = nscale
    for r in nscale["rows"]:
        print(
            f"nscale/n={r['n']},{r['total'] * 1e6:.0f},"
            f"path={r['path']};candidates_s={r['candidates']}"
        )
    print(
        f"nscale/slope,{nscale['slope_candidates'] * 1e6:.0f},"
        f"slope_candidates={nscale['slope_candidates']}"
    )
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_pipeline.json")
    with open(out, "w") as f:
        json.dump(pipeline, f, indent=1)
        f.write("\n")


def _git_sha() -> str:
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def pipeline_bench(n: int = 4000, d: int = 8, kmax: int = 16, seed: int = 0,
                   warm_reps: int = 5) -> dict:
    """Stable-schema per-stage pipeline timings (written to BENCH_pipeline.json).

    Each path runs once cold, then ``warm_reps`` warm repetitions; the warm
    rows report the FASTEST repetition (steady-state compute — a single warm
    sample is hostage to host scheduling noise), with the cold totals kept
    alongside (compile cost is a real deployment quantity too).

    Schema (keys are append-only from PR 2 onward — perf trajectory tooling
    diffs this file across commits, so never rename or remove a key):

      schema_version, config{n,d,kmax,backend,plan}, multi{knn,rng_build,
      mst_range,hierarchy,total}, baseline{knn,mst,hierarchy,total},
      cold{multi_total,baseline_total}, edges{rng,complete},
      speedup_vs_baseline
      + (v2) provenance{git_sha,config_hash,warm_reps}
      + (v3) serve{batch,n_queries,p50_ms,p95_ms,queries_per_s,mean_batch}
        — warm out-of-sample latency through serve.ClusterServeEngine
      + (v4) artifact{save_ms,load_ms,bytes} — FittedModel save/load cost
        at this n (the refit-free serve-worker boot path)
      + (v5) nscale{sizes,d,kmax,rows,slope_candidates} — dual-tree
        n-scaling sweep 10^3 -> 10^5 with per-n stage seconds and the
        fitted log-log slope of the candidate stage (attached by
        ``main()`` via ``nscale_sweep()``; the n=10^5 row is the routine
        large-n benchmark row)
        (tools/check_readme.py fails the docs lane if any of these fields,
        the provenance block, or the artifact block ever goes missing)

    ``provenance.config_hash`` is the sha256 of the canonical config dict, so
    the perf trajectory across commits is attributable: rows only compare
    when both the code (git_sha) and the workload (config_hash) are known.
    """
    import hashlib
    import json as json_mod
    import time

    from benchmarks import paper_sweeps
    from repro import engine
    from repro.core import multi

    x = paper_sweeps._dataset(n, d, seed)
    plan = engine.resolve_plan("auto")

    def timed(fn):
        t0 = time.monotonic()
        out = fn()
        return out, time.monotonic() - t0

    mpts = list(range(2, kmax + 1))
    (_, cold_multi) = timed(lambda: multi.multi_hdbscan(x, kmax, plan=plan))
    (_, cold_base) = timed(lambda: multi.hdbscan_baseline(x, mpts, kmax=kmax, plan=plan))
    import gc

    res, wall_multi = None, float("inf")
    tb, wall_base = None, float("inf")
    for _ in range(max(1, warm_reps)):
        gc.collect()
        (r_m, w_m) = timed(lambda: multi.multi_hdbscan(x, kmax, plan=plan))
        if w_m < wall_multi:
            res, wall_multi = r_m, w_m
        gc.collect()
        ((_, t_b), w_b) = timed(
            lambda: multi.hdbscan_baseline(x, mpts, kmax=kmax, plan=plan)
        )
        if w_b < wall_base:
            tb, wall_base = t_b, w_b

    serve, artifact = serve_bench(x, kmax=kmax, plan=plan, seed=seed)

    config = {
        "n": n, "d": d, "kmax": kmax,
        "backend": plan.backend, "plan": plan.describe(),
    }
    stage = lambda t, k: round(t.get(k, 0.0), 4)  # noqa: E731
    return {
        "schema_version": 5,
        "config": config,
        "provenance": {
            "git_sha": _git_sha(),
            "config_hash": hashlib.sha256(
                json_mod.dumps(config, sort_keys=True).encode()
            ).hexdigest()[:16],
            "warm_reps": warm_reps,
        },
        "multi": {
            "knn": stage(res.timings, "knn"),
            "rng_build": stage(res.timings, "rng_build"),
            "mst_range": stage(res.timings, "mst_range"),
            "hierarchy": stage(res.timings, "hierarchy"),
            "total": round(wall_multi, 4),
        },
        "baseline": {
            "knn": stage(tb, "knn"),
            "mst": stage(tb, "mst"),
            "hierarchy": stage(tb, "hierarchy"),
            "total": round(wall_base, 4),
        },
        "cold": {
            "multi_total": round(cold_multi, 4),
            "baseline_total": round(cold_base, 4),
        },
        "edges": {
            "rng": int(len(res.graph.edges)),
            "complete": n * (n - 1) // 2,
        },
        "speedup_vs_baseline": round(wall_base / max(wall_multi, 1e-9), 2),
        "serve": serve,
        "artifact": artifact,
    }


def nscale_sweep(
    sizes: tuple = (1000, 4000, 16000, 50000, 100000),
    d: int = 8,
    kmax: int = 16,
    seed: int = 0,
) -> dict:
    """n-scaling sweep over the dual-tree candidate path, 10^3 -> 10^5.

    Runs the full multi-hierarchy pipeline at each ``n`` with the dual-tree
    candidate tier forced (the tier whose asymptotics the slope guards; the
    auto tier would silently mix the all-pairs-flavored small-n path into
    the fit).  Reports per-n stage seconds plus the least-squares log-log
    slope of the CANDIDATE stage (kNN + candidate-graph build — the stages
    the dual-tree traversal replaced; MST/extraction are already
    edge-linear).  A slope near 1 is the n log n regime the paper's scaling
    figures assume; the slow-lane regression test pins slope < 1.6.
    """
    import dataclasses
    import math
    import time

    from benchmarks import paper_sweeps
    from repro import engine
    from repro.core import multi

    rows = []
    for n in sizes:
        x = paper_sweeps._dataset(n, d, seed)
        plan = dataclasses.replace(
            engine.resolve_plan("auto"), candidate_method="dualtree"
        )
        t0 = time.monotonic()
        res = multi.multi_hdbscan(x, kmax, plan=plan)
        total = time.monotonic() - t0
        t = res.timings
        rows.append({
            "n": int(n),
            "path": "dualtree",
            "knn": round(t.get("knn", 0.0), 4),
            "candidates": round(
                t.get("knn", 0.0) + t.get("rng_build", 0.0), 4
            ),
            "rng_build": round(t.get("rng_build", 0.0), 4),
            "mst_range": round(t.get("mst_range", 0.0), 4),
            "hierarchy": round(t.get("hierarchy", 0.0), 4),
            "total": round(total, 4),
            "edges": int(len(res.graph.edges)),
        })

    # least-squares slope of log(candidate seconds) vs log(n); rows too fast
    # to time reliably (< 5 ms) are excluded from the fit
    pts = [
        (math.log(r["n"]), math.log(r["candidates"]))
        for r in rows
        if r["candidates"] > 5e-3
    ]
    if len(pts) >= 2:
        mx = sum(p[0] for p in pts) / len(pts)
        my = sum(p[1] for p in pts) / len(pts)
        num = sum((p[0] - mx) * (p[1] - my) for p in pts)
        den = sum((p[0] - mx) ** 2 for p in pts)
        slope = num / den if den else float("nan")
    else:
        slope = float("nan")
    return {
        "sizes": [int(n) for n in sizes],
        "d": d,
        "kmax": kmax,
        "rows": rows,
        "slope_candidates": round(slope, 4),
    }


def artifact_bench(model, reps: int = 3) -> dict:
    """FittedModel save/load cost: best-of-``reps`` wall ms + artifact bytes.

    This is the serve-worker boot path (fit once anywhere, ``load()``
    everywhere), so load is measured cold-cache per rep: a fresh
    ``FittedModel.load`` from disk each time.
    """
    import os
    import tempfile
    import time

    from repro.api import FittedModel

    save_s = load_s = float("inf")
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "model.fitted.npz")
        for _ in range(max(1, reps)):
            t0 = time.monotonic()
            model.save(path)
            save_s = min(save_s, time.monotonic() - t0)
        nbytes = os.path.getsize(path)
        for _ in range(max(1, reps)):
            t0 = time.monotonic()
            FittedModel.load(path)
            load_s = min(load_s, time.monotonic() - t0)
    return {
        "save_ms": round(save_s * 1e3, 2),
        "load_ms": round(load_s * 1e3, 2),
        "bytes": int(nbytes),
    }


def serve_bench(
    x, *, kmax: int, plan, seed: int = 0, batch: int = 64, waves: int = 8
) -> tuple[dict, dict]:
    """Warm out-of-sample serving latency through the ClusterServeEngine,
    plus the artifact save/load cost of the same fitted state.

    One engine over a fitted estimator; ``waves`` bursts of ``batch``
    concurrent single-query clients (the micro-batcher fuses each burst
    into device passes).  The first wave is warmup (compiles the attach
    program family) and is excluded from the reported percentiles.
    Returns ``(serve_section, artifact_section)``.
    """
    import numpy as np

    from repro.api import MultiHDBSCAN
    from repro.serve import ClusterServeEngine

    rng = np.random.default_rng(seed + 1)
    est = MultiHDBSCAN(kmax=kmax, plan=plan).fit(x)
    artifact = artifact_bench(est.model_)
    queries = (
        x[rng.choice(len(x), size=waves * batch)]
        + rng.normal(0, 0.05, size=(waves * batch, x.shape[1]))
    ).astype(x.dtype)

    with ClusterServeEngine(est, max_batch=batch) as eng:
        mid = kmax // 2
        for wave in range(waves):
            futs = [
                eng.submit_predict(queries[wave * batch + i], mpts=mid)
                for i in range(batch)
            ]
            for f in futs:
                f.result(timeout=120)
            if wave == 0:
                eng.reset_stats()  # warmup wave: compiles, not steady state
        stats = eng.stats()
    return {
        "batch": batch,
        "n_queries": stats["n_queries"],
        "p50_ms": stats["p50_ms"],
        "p95_ms": stats["p95_ms"],
        "queries_per_s": stats["queries_per_s"],
        "mean_batch": stats["mean_batch"],
    }, artifact


if __name__ == "__main__":
    main()
