"""Multi-density exploration with the `MultiHDBSCAN` estimator.

Fits once, then walks the whole mpts range interactively-cheap: which density
level reveals which cluster structure (paper §I motivation), scored with the
per-level stability summary.  `--sweep` additionally reproduces the paper
Table II / Fig 7 runtime harness.

  PYTHONPATH=src python examples/multi_density_explore.py [--sweep] [--full]
"""

import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np

from repro.api import MultiHDBSCAN


def explore(n: int, kmax: int):
    rng = np.random.default_rng(7)
    # structure at two density scales: tight twins + one diffuse blob + noise
    x = np.concatenate([
        rng.normal((0, 0), 0.25, size=(n // 4, 2)),
        rng.normal((1.6, 0), 0.25, size=(n // 4, 2)),
        rng.normal((8, 6), 1.4, size=(n // 3, 2)),
        rng.uniform(-4, 12, size=(n - n // 4 * 2 - n // 3, 2)),
    ]).astype(np.float32)

    est = MultiHDBSCAN(kmax=kmax).fit(x)
    print(f"fitted n={len(x)} in "
          f"{sum(v for k, v in est.timings_.items()):.2f}s "
          f"(mpts range [2, {kmax}] from ONE graph)\n")

    print(f"{'mpts':>5} {'clusters':>9} {'noise':>6} {'largest':>8} {'total_stab':>11}")
    for row in est.mpts_profile():
        largest = max(row["cluster_sizes"], default=0)
        print(f"{row['mpts']:>5} {row['n_clusters']:>9} {row['n_noise']:>6} "
              f"{largest:>8} {row['total_stability']:>11.1f}")

    # rank by stability among non-shattered levels (tiny mpts inflates the
    # lambda scale; see MultiHDBSCAN.mpts_profile docs)
    candidates = [r for r in est.mpts_profile() if r["n_clusters"] <= len(x) ** 0.5]
    best = max(candidates, key=lambda r: r["total_stability"])
    print(f"\nhighest-stability level: mpts={best['mpts']} "
          f"({best['n_clusters']} clusters) — labels via est.select(mpts).labels.")
    print("low mpts isolates the tight twins; high mpts merges them and")
    print("stabilizes the diffuse blob — one fit exposes both readings.")


def sweep(full: bool):
    from benchmarks.paper_sweeps import kmax_sweep

    kmaxes = (2, 4, 8, 16, 32, 64, 128) if full else (4, 8, 16, 32)
    n = 8000 if full else 3000
    rows = kmax_sweep(kmaxes=kmaxes, n=n, d=8)
    print(f"\n{'kmax':>5} {'method':>10} {'wall_s':>8} {'edges':>10} {'ratio_vs_one':>12}")
    for r in rows:
        print(f"{r['kmax']:>5} {r['method']:>10} {r['wall_s']:>8.2f} "
              f"{r['edges']:>10,} {r.get('ratio_vs_one', float('nan')):>12}")
    print("\n(paper Table II: baseline grows linearly in kmax; RNG* stays ~flat;")
    print(" paper Fig 7: RNG* ratio ~2 at kmax=128 — same shape here.)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true", help="paper Table II harness")
    ap.add_argument("--full", action="store_true", help="larger sweep")
    ap.add_argument("--n", type=int, default=2400)
    ap.add_argument("--kmax", type=int, default=24)
    args = ap.parse_args()
    if args.sweep:
        sweep(args.full)
    else:
        explore(args.n, args.kmax)


if __name__ == "__main__":
    main()
