"""Public API for the multi-density clustering engine.

Three layers (see docs/architecture.md "Public API & artifacts"):

    from repro.api import FittedModel, SelectionPolicy, MultiHDBSCAN

    # 1) the fitted artifact — fit once, save, load anywhere, no refit
    model = FittedModel.fit(x, kmax=32)
    model.save("blobs.fitted.npz")
    model = FittedModel.load("blobs.fitted.npz")     # milliseconds

    # 2) Clustering query views — selection is per-query state
    c = model.select(8)                              # default policy (eom)
    c.labels, c.probabilities, c.exemplars, c.condensed_tree
    leaf = model.select(8, SelectionPolicy(method="leaf", epsilon=0.25))
    every_level = model.select_all()                 # one device pass

    labels, probs = model.approximate_predict(q, mpts=8)   # out-of-sample

    # 3) sklearn-style estimator wrapper over the same model
    est = MultiHDBSCAN(kmax=32).fit(x)
    est.model_.select(8).labels                      # the model is est.model_
"""

from .estimator import Membership, MultiHDBSCAN
from .model import ArtifactError, Clustering, FittedModel
from .selection import SelectionPolicy

__all__ = [
    "ArtifactError",
    "Clustering",
    "FittedModel",
    "Membership",
    "MultiHDBSCAN",
    "SelectionPolicy",
]
