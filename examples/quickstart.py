"""Quickstart: one hundred hierarchies for the cost of ~two (paper headline).

Builds a clustered dataset, runs the multi-mpts engine once, compares against
the optimized rerun baseline, and verifies the hierarchies agree.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import multi


def main():
    rng = np.random.default_rng(0)
    centers = rng.uniform(-10, 10, size=(8, 8))
    x = np.concatenate(
        [rng.normal(c, 1.0, size=(500, 8)) for c in centers]
    ).astype(np.float32)
    kmax = 32
    print(f"dataset: n={len(x)}, d={x.shape[1]}, mpts range [2, {kmax}]")

    t0 = time.monotonic()
    res = multi.multi_hdbscan(x, kmax, variant="rng_star")
    t_multi = time.monotonic() - t0
    print(f"\nRNG*-HDBSCAN*: {len(res.hierarchies)} hierarchies in {t_multi:.2f}s")
    print(f"  graph edges: {len(res.graph.edges):,} "
          f"(complete graph: {len(x)*(len(x)-1)//2:,})")
    print("  timings:", {k: round(v, 2) for k, v in res.timings.items()})

    t0 = time.monotonic()
    base, tb = multi.hdbscan_baseline(x, [kmax])
    t_one = time.monotonic() - t0
    print(f"\nbaseline, ONE hierarchy (mpts={kmax}): {t_one:.2f}s")
    print(f"=> {len(res.hierarchies)} hierarchies for "
          f"{t_multi / t_one:.1f}x the cost of one (paper: ~2x at kmax=128)")

    h = base[0]
    ours = [hh for hh in res.hierarchies if hh.mpts == kmax][0]
    np.testing.assert_allclose(
        np.sort(ours.mst_w), np.sort(h.mst_w), rtol=1e-5, atol=1e-6
    )
    print("\nMST weight multisets agree with the baseline — hierarchies are exact.")

    print("\nclusters per mpts (sampled):")
    for hh in res.hierarchies[:: max(1, len(res.hierarchies) // 8)]:
        noise = int((hh.labels == -1).sum())
        print(f"  mpts={hh.mpts:3d}: {hh.n_clusters:3d} clusters, {noise:4d} noise pts")


if __name__ == "__main__":
    main()
