"""repro: multi-density clustering hierarchies (RNG-HDBSCAN*) at pod scale."""

__version__ = "1.2.0"

__all__ = ["MultiHDBSCAN", "Plan", "resolve_plan", "__version__"]


def __getattr__(name):
    # lazy: `import repro` stays cheap; `repro.MultiHDBSCAN` pulls in jax
    if name == "MultiHDBSCAN":
        from .api import MultiHDBSCAN

        return MultiHDBSCAN
    if name in ("Plan", "resolve_plan"):
        from . import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
