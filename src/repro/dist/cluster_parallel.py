"""Cluster-parallel collectives: the clustering pipeline over sharded points.

Points live row-sharded over the mesh's ``data`` axis.  ``ring_knn`` keeps
the classic systolic structure: each shard holds its rows resident, a block
of candidate points circulates once around the ring (``ppermute``), and every
shard folds the visiting block into its running top-k.  Peak memory per shard
is O(n_local * (d + k)), never O(n^2 / P).

``ring_lune_count`` answers the RNG** lune-emptiness queries (kernels'
lune_filter semantics) against the full sharded point set: every shard tests
its local points against the (replicated) edge list and the partial verdicts
are OR-reduced.

``sharded_mst_range`` runs the batched Borůvka with the R-row mpts axis
sharded over the mesh: the rows are independent reweightings of the same
edge list, so each shard solves its rows with zero cross-shard traffic.

These collectives are first-class backends of ``kernels.ops`` (via
``backend="mesh"``) and are normally reached through an ``engine.Plan``
rather than called directly; ``pad_rows`` handles the n-not-divisible case
at that boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P


def pad_rows(x, n_shards: int, fill=0):
    """Pad the leading axis to a multiple of ``n_shards`` (device-side)."""
    n = x.shape[0]
    n_pad = -(-n // n_shards) * n_shards
    if n_pad == n:
        return x
    return jnp.concatenate(
        [x, jnp.full((n_pad - n,) + x.shape[1:], fill, x.dtype)]
    )


def shard_rows(x, mesh, axis: str = "data"):
    """Place an array with its leading axis sharded over ``axis``."""
    spec = P(axis, *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


def replicate(x, mesh):
    """Place an array fully replicated over ``mesh``."""
    return jax.device_put(x, NamedSharding(mesh, P()))


def ring_knn(xs, k: int, mesh, axis: str = "data", n_valid: int | None = None):
    """k nearest neighbours of each point, excluding itself.

    Args:
      xs: (n, d) points, sharded P(axis, None); n must divide the axis size
        (pad with ``pad_rows`` + pass ``n_valid`` otherwise).
      k: neighbours per point.
      mesh: the mesh holding ``axis``.
      n_valid: number of real rows; rows >= n_valid are padding and are never
        reported as neighbours (their own outputs are garbage — slice them).
    Returns:
      (d2, idx): (n, k) ascending squared distances and global indices,
      sharded like the input rows.  Matches ``kernels.ops.knn`` up to f32
      reduction order.
    """
    n_shards = mesh.shape[axis]
    n_valid = xs.shape[0] if n_valid is None else n_valid

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=(P(axis, None), P(axis, None)),
        check_rep=False,
    )
    def f(x_loc):
        nl = x_loc.shape[0]
        me = jax.lax.axis_index(axis)
        rows_g = me * nl + jnp.arange(nl, dtype=jnp.int32)
        xf = x_loc.astype(jnp.float32)
        xn = jnp.sum(xf * xf, axis=-1)

        top_d = jnp.full((nl, k), jnp.inf, jnp.float32)
        top_i = jnp.full((nl, k), jnp.iinfo(jnp.int32).max, jnp.int32)
        blk = x_loc
        for t in range(n_shards):
            src = (me - t) % n_shards
            cols_g = src * nl + jnp.arange(nl, dtype=jnp.int32)
            bf = blk.astype(jnp.float32)
            bn = jnp.sum(bf * bf, axis=-1)
            d2 = xn[:, None] + bn[None, :] - 2.0 * (xf @ bf.T)
            d2 = jnp.maximum(d2, 0.0)
            bad = (rows_g[:, None] == cols_g[None, :]) | (cols_g[None, :] >= n_valid)
            d2 = jnp.where(bad, jnp.inf, d2)
            cand_d = jnp.concatenate([top_d, d2], axis=1)
            cand_i = jnp.concatenate(
                [top_i, jnp.broadcast_to(cols_g[None, :], d2.shape)], axis=1
            )
            # lexicographic (distance, index): deterministic under ties
            cand_d, cand_i = jax.lax.sort((cand_d, cand_i), dimension=1, num_keys=2)
            top_d, top_i = cand_d[:, :k], cand_i[:, :k]
            if t + 1 < n_shards:
                blk = jax.lax.ppermute(
                    blk, axis, [(i, (i + 1) % n_shards) for i in range(n_shards)]
                )
        return top_d, top_i

    return f(xs)


def ring_lune_count(xs, cd2s, ea, eb, w2, mesh, axis: str = "data",
                    n_valid: int | None = None):
    """For each edge: is some point strictly inside its mrd lune?

    Args:
      xs: (n, d) points sharded P(axis, None); cd2s: (n,) squared core
      distances sharded P(axis); ea, eb, w2: (m,) replicated edge endpoints
      and squared mrd weights.
      n_valid: number of real rows; padded rows (>= n_valid, zero-filled) are
      never counted as lune occupants.
    Returns:
      (m,) bool, replicated — same verdicts as kernels.ref.lune_filter_ref
      (including its norm-scaled keep-only cancellation margin).
    """
    n_shards = mesh.shape[axis]
    m = ea.shape[0]
    n_valid = xs.shape[0] if n_valid is None else n_valid

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(), P(), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    def f(x_loc, cd2_loc, ea, eb, w2):
        nl = x_loc.shape[0]
        me = jax.lax.axis_index(axis)
        cols_g = me * nl + jnp.arange(nl, dtype=jnp.int32)

        # endpoint coordinates via one-hot gather from the sharded rows:
        # each shard contributes its resident endpoints; psum completes them.
        def gather_rows(idx):
            onehot = (idx[:, None] == cols_g[None, :]).astype(jnp.float32)
            xg = jax.lax.psum(onehot @ x_loc.astype(jnp.float32), axis)
            cg = jax.lax.psum(onehot @ cd2_loc.astype(jnp.float32), axis)
            ng = jax.lax.psum(
                onehot @ jnp.sum(x_loc.astype(jnp.float32) ** 2, -1), axis
            )
            return xg, cg, ng

        a_xyz, a_cd2, an = gather_rows(ea)
        b_xyz, b_cd2, bn = gather_rows(eb)

        xf = x_loc.astype(jnp.float32)
        cn = jnp.sum(xf * xf, axis=-1)[None, :]
        d2_ac = jnp.maximum(an[:, None] + cn - 2.0 * (a_xyz @ xf.T), 0.0)
        d2_bc = jnp.maximum(bn[:, None] + cn - 2.0 * (b_xyz @ xf.T), 0.0)
        mrd_ac = jnp.maximum(jnp.maximum(d2_ac, a_cd2[:, None]), cd2_loc[None, :])
        mrd_bc = jnp.maximum(jnp.maximum(d2_bc, b_cd2[:, None]), cd2_loc[None, :])
        eps = jnp.float32(64.0 * 1.1920929e-07)
        skip = (
            (cols_g[None, :] == ea[:, None])
            | (cols_g[None, :] == eb[:, None])
            | (cols_g[None, :] >= n_valid)
        )
        inside = (
            jnp.maximum(mrd_ac + eps * (an[:, None] + cn), mrd_bc + eps * (bn[:, None] + cn))
            < w2[:, None]
        ) & ~skip
        return jnp.any(inside, axis=1)  # (m,) partial verdict for local points

    partial_flat = f(xs, cd2s, ea, eb, w2)  # (n_shards * m,) row-sharded
    return jnp.any(partial_flat.reshape(n_shards, m), axis=0)


def sharded_mst_range(ea, eb, w_range, *, n: int, mesh, axis: str = "data"):
    """Batched Borůvka with the R independent mpts rows sharded over ``axis``.

    Each row of ``w_range`` is one reweighting of the same (replicated) edge
    list — embarrassingly parallel, so every shard runs its rows' full
    Borůvka loop locally with no per-round collective.  R is padded to a
    multiple of the axis size with copies of the last row (same weights =>
    same converged MST; padded rows are sliced off).

    Returns in_mst (R, m) bool, same semantics as boruvka_mst_range.
    """
    from ..core import boruvka  # function-level: dist must stay core-free at import

    n_shards = mesh.shape[axis]
    R = w_range.shape[0]
    R_pad = -(-R // n_shards) * n_shards
    if R_pad != R:
        w_range = jnp.concatenate(
            [w_range, jnp.broadcast_to(w_range[-1:], (R_pad - R, w_range.shape[1]))]
        )
    w_s = shard_rows(jnp.asarray(w_range), mesh, axis)
    ea_r = replicate(jnp.asarray(ea, jnp.int32), mesh)
    eb_r = replicate(jnp.asarray(eb, jnp.int32), mesh)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(axis, None)),
        out_specs=P(axis, None),
        check_rep=False,
    )
    def f(ea_l, eb_l, w_l):
        # the UNJITTED body: an inner jit nested under shard_map miscompiles
        # the flat-scatter while_loop on multi-device CPU (see core.boruvka)
        return boruvka._boruvka_mst_range(ea_l, eb_l, w_l, n=n)

    return f(ea_r, eb_r, w_s)[:R]
