from .base import ARCH_IDS, SHAPES, ModelConfig, all_configs, get_config

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "all_configs", "get_config"]
