"""Well-Separated Pair Decomposition with the paper's mrd-aware predicate.

Host-side control plane (numpy): the fair-split tree and the pair recursion
are pointer-chasing scalar work — O(n log n) node operations — which a real
accelerator deployment keeps on the driver CPU (DESIGN.md §3).  All O(n^2)
distance work consumes the *output* of this module on device.

Well-separation (paper §IV-E, adapting Callahan-Kosaraju):

    D(A, B) >= s * max{ diam(B_A), diam(B_B), max_{p in A u B} c_kmax(p) }

where ``B_X`` is the ball circumscribing the bounding box of X and ``D`` is
the (lower-bounded) distance between the two balls.  ``s = 1``.

Termination note: with the core-distance term two *singleton* nodes can be
impossible to separate (d(a,b) < max core dist) and cannot be split further;
such pairs are emitted anyway — for singletons the pair IS its own SBCN edge,
so emitting it preserves the RNG-superset property (it only ever ADDS a
candidate edge).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FairSplitTree:
    """Array-encoded fair-split tree over a permutation of point indices."""

    perm: np.ndarray        # (n,)  point indices, contiguous per node
    start: np.ndarray       # (n_nodes,) range start into perm
    end: np.ndarray         # (n_nodes,) range end (exclusive)
    left: np.ndarray        # (n_nodes,) child id or -1
    right: np.ndarray       # (n_nodes,)
    center: np.ndarray      # (n_nodes, d) bbox center
    radius: np.ndarray      # (n_nodes,)  half bbox diagonal (ball radius)
    max_cd: np.ndarray      # (n_nodes,)  max core distance (NOT squared) in node

    @property
    def n_nodes(self) -> int:
        return self.start.shape[0]

    def points(self, u: int) -> np.ndarray:
        return self.perm[self.start[u] : self.end[u]]


def build_fair_split_tree(x: np.ndarray, cd_kmax: np.ndarray) -> FairSplitTree:
    """Midpoint-split fair-split tree; leaves are single points."""
    n, _ = x.shape
    max_nodes = 2 * n - 1
    perm = np.arange(n)
    start = np.zeros(max_nodes, np.int64)
    end = np.zeros(max_nodes, np.int64)
    left = np.full(max_nodes, -1, np.int64)
    right = np.full(max_nodes, -1, np.int64)
    centers = np.zeros((max_nodes, x.shape[1]), np.float64)
    radii = np.zeros(max_nodes, np.float64)
    max_cd = np.zeros(max_nodes, np.float64)

    node_count = 1
    start[0], end[0] = 0, n
    stack = [0]
    while stack:
        u = stack.pop()
        s, e = start[u], end[u]
        idx = perm[s:e]
        pts = x[idx]
        lo, hi = pts.min(axis=0), pts.max(axis=0)
        centers[u] = (lo + hi) / 2.0
        radii[u] = 0.5 * float(np.linalg.norm(hi - lo))
        max_cd[u] = float(cd_kmax[idx].max())
        if e - s == 1:
            continue
        dim = int(np.argmax(hi - lo))
        mid = 0.5 * (lo[dim] + hi[dim])
        mask = pts[:, dim] <= mid
        if mask.all() or not mask.any():
            # Degenerate (coincident coords): median split by order.
            order = np.argsort(pts[:, dim], kind="stable")
            half = (e - s) // 2
            mask = np.zeros(e - s, bool)
            mask[order[:half]] = True
        perm[s:e] = np.concatenate([idx[mask], idx[~mask]])
        nl = int(mask.sum())
        lid, rid = node_count, node_count + 1
        node_count += 2
        left[u], right[u] = lid, rid
        start[lid], end[lid] = s, s + nl
        start[rid], end[rid] = s + nl, e
        stack.append(lid)
        stack.append(rid)

    sl = slice(0, node_count)
    return FairSplitTree(
        perm=perm,
        start=start[sl].copy(),
        end=end[sl].copy(),
        left=left[sl].copy(),
        right=right[sl].copy(),
        center=centers[sl].copy(),
        radius=radii[sl].copy(),
        max_cd=max_cd[sl].copy(),
    )


def wspd_pairs(tree: FairSplitTree, s: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
    """Enumerate well-separated pairs w.r.t. the mrd predicate.

    Level-synchronous vectorized search: the work list of candidate (u, v)
    node pairs is processed as whole numpy arrays per round (the recursion
    depth is O(log n + split chain), so ~tens of rounds regardless of the
    pair count).  Returns (U, V) arrays of node ids.
    """
    center, radius, max_cd = tree.center, tree.radius, tree.max_cd
    left, right = tree.left, tree.right
    size = tree.end - tree.start

    internal = np.nonzero(left != -1)[0]
    U = left[internal]
    V = right[internal]
    out_u: list[np.ndarray] = []
    out_v: list[np.ndarray] = []
    while len(U):
        d_centers = np.linalg.norm(center[U] - center[V], axis=1)
        dist_lb = np.maximum(0.0, d_centers - radius[U] - radius[V])
        rhs = s * np.maximum(
            np.maximum(2.0 * radius[U], 2.0 * radius[V]),
            np.maximum(max_cd[U], max_cd[V]),
        )
        sep = dist_lb >= rhs
        # unsplittable singleton-singleton pairs are emitted (module docstring)
        emit = sep | ((size[U] == 1) & (size[V] == 1))
        out_u.append(U[emit])
        out_v.append(V[emit])
        U, V = U[~emit], V[~emit]
        if not len(U):
            break
        # split the "bigger" node (by ball radius, then size)
        su = (radius[U] > radius[V]) | (
            (radius[U] == radius[V]) & (size[U] >= size[V])
        )
        Us, Vs = U[su], V[su]
        Uo, Vo = U[~su], V[~su]
        U = np.concatenate([left[Us], right[Us], Uo, Uo])
        V = np.concatenate([Vs, Vs, left[Vo], right[Vo]])
    return np.concatenate(out_u), np.concatenate(out_v)
