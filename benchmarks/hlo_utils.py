"""Loop-aware roofline terms from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count — useless for scan-over-layers models.  This module re-derives the
three roofline inputs directly from the optimized HLO:

  * FLOPs       — every ``dot``/``convolution`` (2*M*N*K from shapes), inside
                  fusions too, multiplied through the call graph by while-loop
                  trip counts.
  * HBM bytes   — post-fusion operand+output bytes of top-level instructions
                  (fusion internals don't touch HBM; that's exactly XLA's own
                  accounting), with the same loop multipliers.
  * collective bytes — operand bytes of all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute.

Trip counts are recovered from while-condition computations of the canonical
``compare(get-tuple-element(param), constant)`` form; anything unrecognized
falls back to multiplier 1 with a warning flag in the result.

This is a structural estimator (dry-run profiling, no hardware): exact for
dots, approximate for bytes (assumes every top-level operand/result is an HBM
round trip; XLA may keep some in registers/VMEM).
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\)\s*->|{)")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes inside a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_elems(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, 0
    dtype, dims = m.groups()
    n = 1
    dd = []
    for d in dims.split(","):
        if d.strip():
            dd.append(int(d))
            n *= int(d)
    return dd, n


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))


class _Instr:
    __slots__ = ("name", "shape", "op", "rest")

    def __init__(self, name, shape, op, rest):
        self.name = name
        self.shape = shape
        self.op = op
        self.rest = rest


def _parse_computations(hlo: str) -> dict[str, list[_Instr]]:
    """Computation headers sit at column 0 (`%name (args) -> ret {` possibly
    with nested tuple parens); instructions are indented.  Indentation is the
    reliable discriminator — regexing the arg list is not."""
    comps: dict[str, list[_Instr]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\{)", line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            comps[cur].append(_Instr(*im.groups()))
    return comps


def _dot_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    """2 * prod(output dims) * K.  K from contracting dims of operand 0."""
    out_dims, out_elems = _first_shape_elems(instr.shape)
    if out_dims is None:
        return 0.0
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    ops = re.findall(r"%?([\w.\-]+)", instr.rest.split("),")[0])
    # find first operand name with a known shape
    k = 1
    lhs_shape = None
    for name in re.findall(r"%([\w.\-]+)", instr.rest):
        if name in symtab:
            lhs_shape = symtab[name]
            break
    if cm and lhs_shape:
        dims, _ = _first_shape_elems(lhs_shape)
        if dims:
            for ci in cm.group(1).split(","):
                ci = ci.strip()
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    out_dims, out_elems = _first_shape_elems(instr.shape)
    if out_dims is None:
        return 0.0
    # approximate: 2 * out_elems * (kernel window elems * in_channels)
    names = re.findall(r"%([\w.\-]+)", instr.rest)
    if len(names) >= 2 and names[1] in symtab:
        kd, ke = _first_shape_elems(symtab[names[1]])
        if kd:
            return 2.0 * out_elems * (ke // max(kd[-1], 1))
    return 2.0 * out_elems


_COLL_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SLICE_OPS = ("dynamic-slice", "gather")


def _fusion_bytes(fname: str, comps, out_shape: str, operand_shapes: list[str]) -> float:
    """HBM traffic of one fusion call, usage-aware:

      * a parameter consumed ONLY by dynamic-slice/gather inside the body
        contributes the SLICE bytes, not the full buffer (the residual-stack
        gathers in scan backwards otherwise overcount by ~L x);
      * a dynamic-update-slice whose buffer matches the fusion output is an
        in-place RMW: neither the buffer param nor the output is traffic —
        only the update region (counted via its own param) is;
      * everything else: full param size; plus the output unless aliased.
    """
    body = comps.get(fname)
    if body is None:
        return _shape_bytes(out_shape) + sum(_shape_bytes(s) for s in operand_shapes)
    param_shapes = {i.name: i.shape for i in body if i.op == "parameter"}
    # alias map: instruction -> source param through unary pass-through chains
    _PASS = {"bitcast", "copy", "reshape", "transpose", "convert", "broadcast"}
    alias: dict[str, str] = {p: p for p in param_shapes}
    for i in body:
        if i.op in _PASS:
            refs = re.findall(r"%([\w.\-]+)", i.rest)
            if refs and refs[0] in alias:
                alias[i.name] = alias[refs[0]]
    used_full: set[str] = set()
    sliced: dict[str, float] = {}
    aliased: set[str] = set()
    out_aliased = False
    for i in body:
        if i.op in _PASS:
            continue  # pass-through: judged at the consuming op
        refs = [
            alias[r]
            for r in re.findall(r"%([\w.\-]+)", i.rest)
            if r in alias
        ]
        for r in refs:
            if i.op in _SLICE_OPS:
                sliced[r] = sliced.get(r, 0.0) + _shape_bytes(i.shape)
            elif i.op == "dynamic-update-slice" and _shape_bytes(
                param_shapes[r]
            ) == _shape_bytes(out_shape) and _shape_bytes(out_shape) > 0:
                aliased.add(r)
                out_aliased = True
            else:
                used_full.add(r)
    total = 0.0
    for pname, shape in param_shapes.items():
        if pname in used_full:
            total += _shape_bytes(shape)
        elif pname in sliced:
            total += min(sliced[pname], _shape_bytes(shape))
        # aliased / unused: 0
    if not out_aliased:
        total += _shape_bytes(out_shape)
    return total

_CHEAP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
}


def _trip_count(cond_name: str, comps: dict[str, list[_Instr]]) -> int | None:
    """Recover trip count from a while condition.

    Canonical post-opt form: the condition holds `constant(N)` and a fusion
    wrapping `compare(induction, bound), direction=LT` (or a bare compare).
    Induction starts at 0 in lax.scan lowerings, so the bound IS the trip
    count.  With several integer constants we take the max (scan bounds
    dominate stray 0/1 constants); unrecognized structures return None.
    """
    if cond_name not in comps:
        return None
    reach = [cond_name]
    for ins in comps[cond_name]:
        if ins.op.startswith("fusion"):
            m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
            if m:
                reach.append(m.group(1))
    has_lt = False
    consts: list[int] = []
    for cname in reach:
        for ins in comps.get(cname, []):
            if ins.op == "compare" and "direction=LT" in ins.rest:
                has_lt = True
            if ins.op == "constant":
                m = re.match(r"(-?\d+)\)", ins.rest)  # rest starts after '('
                if m:
                    consts.append(int(m.group(1)))
    if has_lt and consts:
        trips = max(consts)
        # XLA CPU expands scatter/sort into element-wise while loops with
        # million-scale trip counts; multiplying full-operand bytes by those
        # produces absurd terms (observed: 2.6e9 ms "memory" on a Boruvka
        # program).  Program-level scan/layer loops in this codebase are
        # <= a few thousand trips; cap and let the caller flag it.
        if trips > 100_000:
            return None
        return max(trips, 1)
    return None


def analyze_hlo(hlo: str) -> HloStats:
    comps = _parse_computations(hlo)
    stats = HloStats()

    # symbol table per computation: instr name -> shape string
    symtabs = {
        cname: {i.name: i.shape for i in instrs} for cname, instrs in comps.items()
    }

    # compute per-computation local cost, then propagate through call graph
    memo: dict[str, tuple[float, float, dict]] = {}

    def comp_cost(cname: str, depth=0) -> tuple[float, float, dict]:
        if cname in memo:
            return memo[cname]
        if depth > 64 or cname not in comps:
            return (0.0, 0.0, {})
        flops = 0.0
        byts = 0.0
        coll: dict[str, float] = {}
        symtab = symtabs[cname]
        for ins in comps[cname]:
            if ins.op == "dot":
                flops += _dot_flops(ins, symtab)
            elif ins.op == "convolution":
                flops += _conv_flops(ins, symtab)
            if ins.op.startswith("fusion"):
                m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                operand_shapes = [
                    symtab[n]
                    for n in re.findall(r"%([\w.\-]+)", ins.rest.split("calls=")[0])
                    if n in symtab
                ]
                if m:
                    f_fl, _, f_coll = comp_cost(m.group(1), depth + 1)
                    flops += f_fl
                    for k, v in f_coll.items():
                        coll[k] = coll.get(k, 0.0) + v
                    byts += _fusion_bytes(m.group(1), comps, ins.shape, operand_shapes)
                else:
                    byts += _shape_bytes(ins.shape) + sum(
                        _shape_bytes(s) for s in operand_shapes
                    )
            elif ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trips = None
                if cm:
                    trips = _trip_count(cm.group(1), comps)
                if trips is None:
                    trips = 1
                    stats.unknown_trip_counts += 1
                if bm:
                    b_fl, b_by, b_coll = comp_cost(bm.group(1), depth + 1)
                    flops += trips * b_fl
                    byts += trips * b_by
                    for k, v in b_coll.items():
                        coll[k] = coll.get(k, 0.0) + trips * v
            elif ins.op in ("call", "conditional", "custom-call", "map", "sort", "reduce", "scatter", "select-and-scatter", "reduce-window"):
                for m in re.finditer(r"(?:calls|to_apply|branch_computations=\{)[=%]*([\w.\-]+)", ins.rest):
                    c_fl, c_by, c_coll = comp_cost(m.group(1), depth + 1)
                    flops += c_fl
                    byts += c_by
                    for k, v in c_coll.items():
                        coll[k] = coll.get(k, 0.0) + v
                byts += _shape_bytes(ins.shape)
            elif ins.op.startswith(_COLL_OPS):
                opname = next(c for c in _COLL_OPS if ins.op.startswith(c))
                sz = 0
                for name in re.findall(r"%([\w.\-]+)", ins.rest):
                    if name in symtab:
                        sz += _shape_bytes(symtab[name])
                if sz == 0:
                    sz = _shape_bytes(ins.shape)
                coll[opname] = coll.get(opname, 0.0) + sz
                byts += _shape_bytes(ins.shape)
            elif ins.op in _SLICE_OPS:
                byts += 2.0 * _shape_bytes(ins.shape)  # read slice + write out
            elif ins.op == "dynamic-update-slice":
                # in-place RMW: traffic = the update region (2nd operand)
                names = re.findall(r"%([\w.\-]+)", ins.rest)
                upd = symtab.get(names[1]) if len(names) > 1 else None
                byts += 2.0 * _shape_bytes(upd) if upd else _shape_bytes(ins.shape)
            elif ins.op not in _CHEAP_OPS and not ins.op.startswith("fusion"):
                # top-level non-fused op: operands + result move through HBM
                byts += _shape_bytes(ins.shape)
                for name in set(re.findall(r"%([\w.\-]+)", ins.rest)):
                    if name in symtab:
                        byts += _shape_bytes(symtab[name])
        memo[cname] = (flops, byts, coll)
        return memo[cname]

    # entry computation: the one whose name appears after ENTRY
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry:
        fl, by, coll = comp_cost(entry)
        stats.flops = fl
        stats.bytes_hbm = by
        stats.coll_bytes = coll
    return stats


# ---------------------------------------------------------------------------
# roofline terms (v5e constants per the task statement)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 49.5e9            # bytes/s / link


def roofline_terms(stats: HloStats, n_chips: int, *, per_device_hlo: bool = True):
    """The three times (seconds). HLO from a compiled SPMD module is already
    per-device (shapes are shard-local), so divide only when it's global."""
    div = 1 if per_device_hlo else n_chips
    t_compute = stats.flops / div / PEAK_FLOPS
    t_memory = stats.bytes_hbm / div / HBM_BW
    t_coll = stats.collective_bytes / div / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }
