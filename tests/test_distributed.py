"""Multi-device tests run in subprocesses (8 fake CPU devices) so the main
pytest process keeps its single-device view."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_ring_knn_matches_local():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.dist.cluster_parallel import ring_knn
    from repro.kernels import ops
    from repro.launch.mesh import make_mesh_compat
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 5)).astype(np.float32))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    d2, idx = ring_knn(xs, 7, mesh)
    d2_ref, idx_ref = ops.knn(x, 7, backend="jnp", refine_slack=0)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref), rtol=2e-3, atol=1e-5)
    assert (np.asarray(idx) == np.asarray(idx_ref)).mean() > 0.999
    """)


@pytest.mark.slow
def test_ring_lune_matches_local():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.dist.cluster_parallel import ring_knn, ring_lune_count
    from repro.kernels import ref as kref, ops
    from repro.launch.mesh import make_mesh_compat
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh_compat((8,), ("data",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(240, 4)).astype(np.float32))
    d2, _ = ops.knn(x, 6, backend="jnp")
    cd2 = d2[:, 4]
    ea = jnp.asarray(rng.integers(0, 240, size=64).astype(np.int32))
    eb = jnp.asarray(rng.integers(0, 240, size=64).astype(np.int32))
    d2ab = jnp.sum((x[ea]-x[eb])**2, -1)
    w2 = jnp.maximum(jnp.maximum(cd2[ea], cd2[eb]), d2ab)
    want = np.asarray(kref.lune_filter_ref(x[ea], x[eb], cd2[ea], cd2[eb], ea, eb, w2, x, cd2))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    cds = jax.device_put(cd2, NamedSharding(mesh, P("data")))
    got = np.asarray(ring_lune_count(xs, cds, ea, eb, w2, mesh))
    assert (got == want).all()
    """)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The jitted train step gives identical losses on 1 device and on a
    4x2 mesh with full sharding rules (GSPMD correctness check)."""
    out = _run("""
    import numpy as np, jax, jax.numpy as jnp, dataclasses
    from repro.configs import get_config
    from repro.models import init_params
    from repro.dist import sharding as shardlib
    from repro.train import optim as optim_mod
    from repro.train.step import make_train_step
    from repro.train import data as data_lib

    cfg = dataclasses.replace(get_config("qwen2_1_5b").reduced(), microbatch=2)
    params, specs = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim_mod.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_init, _ = optim_mod.make_optimizer(opt_cfg)
    dcfg = data_lib.DataConfig(seed=0, vocab=cfg.vocab, seq_len=32, global_batch=8)
    batch = data_lib.train_batch(dcfg, 0)
    step = make_train_step(cfg, opt_cfg)

    # single device
    l1 = float(jax.jit(step)(params, opt_init(params), batch)[2]["loss"])

    # sharded
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    rules = shardlib.resolve_rules(mesh)
    p_sh = shardlib.tree_shardings(specs, mesh, rules)
    params_s = jax.device_put(params, p_sh)
    def step_ctx(p, o, b):
        with shardlib.activation_context(mesh, rules):
            return step(p, o, b)
    l2 = float(jax.jit(step_ctx)(params_s, opt_init(params_s), batch)[2]["loss"])
    print("losses", l1, l2)
    assert abs(l1 - l2) < 5e-3 * max(abs(l1), 1.0), (l1, l2)
    """)


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One full dry-run cell on both meshes (512 fake devices)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2_780m",
         "--shape", "long_500k", "--mesh", "both", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "2 ok" in r.stdout


@pytest.mark.slow
def test_mesh_pipeline_matches_dualtree_tier():
    """Multidevice n-scaling parity: the sharded (mesh) pipeline and the
    single-device dual-tree tier produce bit-identical sorted MST weight
    rows for every mpts.  The mesh path never routes through the dual-tree
    control plane (it is host-side and unsharded), so this pins the two
    large-n strategies — shard the all-pairs stages vs. switch algorithms —
    to the same fixed point."""
    _run("""
    import numpy as np, dataclasses
    from repro import engine
    from repro.core import multi

    rng = np.random.default_rng(5)
    c = rng.uniform(-10, 10, size=(6, 6))
    x = (c[rng.integers(0, 6, 1536)] +
         rng.normal(0, 1.0, size=(1536, 6))).astype(np.float32)

    kmax = 8
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    mesh_plan = engine.resolve_plan("mesh", mesh=mesh)
    assert mesh_plan.sharded, "mesh plan did not shard on 8 fake devices"
    m_mesh = multi.fit_msts(x, kmax, plan=mesh_plan)

    single = engine.resolve_plan("single")
    dt = dataclasses.replace(single, candidate_method="dualtree")
    m_dt = multi.fit_msts(x, kmax, plan=dt)
    assert m_dt.graph.stats.get("path") == "dualtree"

    np.testing.assert_array_equal(
        np.sort(np.asarray(m_mesh.mst_w), axis=1),
        np.sort(np.asarray(m_dt.mst_w), axis=1),
    )
    """)
