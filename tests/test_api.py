"""MultiHDBSCAN estimator: baseline agreement across the whole mpts range,
lazy-cache behaviour, selection methods, profile, and validation errors."""

import numpy as np
import pytest

from repro.api import MultiHDBSCAN
from repro.core import multi


@pytest.fixture(scope="module")
def blobs520():
    """>=500-point blobs dataset (acceptance-criterion scale)."""
    rng = np.random.default_rng(11)
    x = np.concatenate([
        rng.normal((0, 0), 0.35, size=(180, 2)),
        rng.normal((5, 0), 0.5, size=(180, 2)),
        rng.normal((2.5, 4.5), 0.4, size=(130, 2)),
        rng.uniform(-2, 7, size=(30, 2)),
    ]).astype(np.float32)
    return x


@pytest.fixture(scope="module")
def fitted(blobs520):
    return MultiHDBSCAN(kmax=16).fit(blobs520)


def _assert_partitions_agree(a, b, tol=0.98):
    """Same partition up to label permutation and rare tie-boundary points."""
    assert abs((a >= 0).sum() - (b >= 0).sum()) <= max(2, 0.01 * len(a))
    agree = total = 0
    for c in np.unique(a[a >= 0]):
        members = b[a == c]
        members = members[members >= 0]
        if len(members) == 0:
            continue
        _, counts = np.unique(members, return_counts=True)
        agree += counts.max()
        total += counts.sum()
    assert total > 0 and agree / total > tol


def test_labels_match_baseline_every_mpts(blobs520, fitted):
    """Acceptance: labels_for(mpts) == hdbscan_baseline labels for ALL mpts
    in [2, kmax] on a >=500-point dataset."""
    base, _ = multi.hdbscan_baseline(blobs520, list(range(2, 17)))
    for hb in base:
        ours = fitted.labels_for(hb.mpts)
        # exact MST agreement first: weight multisets must match
        _, _, w = fitted.mst_for(hb.mpts)
        np.testing.assert_allclose(
            np.sort(w), np.sort(hb.mst_w), rtol=1e-5, atol=1e-6
        )
        assert abs(int(ours.max()) + 1 - hb.n_clusters) <= 1
        _assert_partitions_agree(ours, hb.labels)


def test_labels_cached_and_idempotent(fitted):
    l1 = fitted.labels_for(5)
    l2 = fitted.labels_for(5)
    assert l1 is l2  # cache hit returns the same array, no recompute
    np.testing.assert_array_equal(l1, fitted.hierarchy_for(5).labels)
    # cache is per-mpts: another level is a different object
    assert fitted.labels_for(6) is not l1


def test_extraction_is_lazy(blobs520):
    est = MultiHDBSCAN(kmax=8).fit(blobs520)
    assert est._linkage is None and not est._hierarchy_cache
    est.labels_for(4)
    assert est._linkage is not None
    assert list(est._hierarchy_cache) == [4]


def test_fit_predict_and_default_level(blobs520):
    labels = MultiHDBSCAN(kmax=8).fit_predict(blobs520)
    assert labels.shape == (len(blobs520),)
    assert labels.max() >= 2  # three blobs at the smoothed end of the range


def test_leaf_selection_refines_eom(blobs520):
    eom = MultiHDBSCAN(kmax=8, min_cluster_size=10).fit(blobs520)
    leaf = MultiHDBSCAN(
        kmax=8, min_cluster_size=10, cluster_selection_method="leaf"
    ).fit(blobs520)
    l_eom, l_leaf = eom.labels_for(8), leaf.labels_for(8)
    assert l_leaf.max() >= l_eom.max()
    for c in np.unique(l_leaf[l_leaf >= 0]):
        parents = l_eom[l_leaf == c]
        assert len(np.unique(parents[parents >= 0])) <= 1


def test_mpts_profile(fitted):
    prof = fitted.mpts_profile()
    assert [r["mpts"] for r in prof] == list(range(2, 17))
    for r in prof:
        assert r["n_clusters"] == len(r["cluster_sizes"])
        assert r["n_noise"] + sum(r["cluster_sizes"]) == fitted.n_samples_
        assert r["total_stability"] >= 0.0
    # the mid-range should recover the 3 planted blobs at some level
    assert any(r["n_clusters"] == 3 for r in prof)


def test_probabilities_for_matches_docstring_promise(blobs520, fitted):
    """The estimator docstring has promised probabilities_for(mpts) since
    PR 1; pin the implementation: [0, 1], 0 for noise, every cluster peaks
    at 1.0, consistent with membership_for."""
    for mpts in (2, 8, 16):
        probs = fitted.probabilities_for(mpts)
        labels = fitted.labels_for(mpts)
        assert probs.shape == (len(blobs520),)
        assert np.all((probs >= 0.0) & (probs <= 1.0))
        assert np.all(probs[labels == -1] == 0.0)
        assert np.all(probs[labels >= 0] > 0.0)
        for c in np.unique(labels[labels >= 0]):
            assert probs[labels == c].max() == pytest.approx(1.0)
        m = fitted.membership_for(mpts)
        np.testing.assert_array_equal(m.probabilities, probs)
        np.testing.assert_array_equal(m.labels, labels)


def test_selected_labels_are_contiguous(blobs520):
    """mpts_profile's ``np.bincount(labels, minlength=n_clusters)`` assumes
    labels_for_fast emits contiguous labels 0..n_clusters-1 with every
    selected cluster non-empty; pin that invariant across selection
    methods, allow_single_cluster, and the whole mpts range."""
    from repro.core import hierarchy

    for method in ("eom", "leaf"):
        for single in (False, True):
            est = MultiHDBSCAN(
                kmax=8,
                cluster_selection_method=method,
                allow_single_cluster=single,
            ).fit(blobs520)
            for mpts in est.mpts_values_:
                h = est.hierarchy_for(mpts)
                present = np.unique(h.labels[h.labels >= 0])
                np.testing.assert_array_equal(
                    present,
                    np.arange(len(present)),
                    err_msg=f"{method}/single={single}/mpts={mpts}",
                )
                assert h.n_clusters == len(h.selected) == len(present)
                # and directly through labels_for_fast (the producer)
                lf, _ = hierarchy.labels_for_fast(h.condensed, h.selected)
                np.testing.assert_array_equal(lf, h.labels)
            prof = est.mpts_profile()
            for row in prof:
                assert sum(row["cluster_sizes"]) + row["n_noise"] == len(blobs520)
                assert all(s > 0 for s in row["cluster_sizes"])


def test_hierarchy_cache_lru_bound(blobs520):
    est = MultiHDBSCAN(kmax=8, max_cached_hierarchies=2).fit(blobs520)
    first = est.labels_for(4).copy()
    est.labels_for(5)
    est.labels_for(6)  # evicts mpts=4
    assert list(est._hierarchy_cache) == [5, 6]
    np.testing.assert_array_equal(est.labels_for(4), first)  # re-extracts
    assert list(est._hierarchy_cache) == [6, 4]
    with pytest.raises(ValueError, match="max_cached_hierarchies"):
        MultiHDBSCAN(kmax=4, max_cached_hierarchies=0)


def test_validation_errors(blobs520):
    with pytest.raises(RuntimeError, match="not fitted"):
        MultiHDBSCAN(kmax=4).labels_for(2)
    with pytest.raises(ValueError, match="cluster_selection_method"):
        MultiHDBSCAN(cluster_selection_method="bogus")
    with pytest.raises(ValueError, match="kmax"):
        MultiHDBSCAN(kmax=1)
    with pytest.raises(ValueError, match="min_cluster_size"):
        MultiHDBSCAN(kmax=4, min_cluster_size=1)
    with pytest.raises(ValueError, match="min_cluster_size"):
        multi.multi_hdbscan(np.zeros((10, 2), np.float32), 4, min_cluster_size=0)
    with pytest.raises(ValueError, match="2-d"):
        MultiHDBSCAN(kmax=4).fit(np.zeros(7))
    with pytest.raises(ValueError, match="exceed kmax"):
        MultiHDBSCAN(kmax=600).fit(blobs520)
    est = MultiHDBSCAN(kmax=8).fit(blobs520)
    with pytest.raises(KeyError, match="not in computed range"):
        est.labels_for(99)


def test_fit_rejects_non_finite_input(blobs520):
    """NaN/inf coordinates must fail fast, before the WSPD control plane and
    the f32 tie machinery see them."""
    x = blobs520.copy()
    x[7, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite.*row 7"):
        MultiHDBSCAN(kmax=4).fit(x)
    x = blobs520.copy()
    x[3, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        MultiHDBSCAN(kmax=4).fit(x)
    with pytest.raises(ValueError, match="numeric"):
        MultiHDBSCAN(kmax=4).fit(np.full((30, 2), "a"))


def test_refit_clears_stale_fitted_state(blobs520):
    """Regression: fit() must reset every trailing-underscore fitted
    attribute — a fit_predict on dataset A followed by fit(B) used to leave
    A's labels_ (wrong length, wrong data) on the refitted estimator."""
    rng = np.random.default_rng(23)
    other = np.concatenate([
        rng.normal((0, 0), 0.3, size=(60, 2)),
        rng.normal((3, 3), 0.3, size=(60, 2)),
    ]).astype(np.float32)

    est = MultiHDBSCAN(kmax=8)
    stale = est.fit_predict(blobs520)
    assert stale.shape == (len(blobs520),)
    est.fit(other)
    assert not hasattr(est, "labels_")  # stale labels from blobs520 are gone
    assert est.n_samples_ == len(other)
    labels = est.fit_predict(other)
    assert labels.shape == (len(other),)
    np.testing.assert_array_equal(est.labels_, labels)


def test_duplicate_heavy_ties_identical_across_backends():
    """Tie-stress regression: massively duplicated points (every mrd value
    tied many ways) must produce IDENTICAL labels across the ref / jnp /
    pallas(interpret) backends for every mpts in the range — the tie-epsilon
    machinery and the fused cascade's overflow fallback may never let
    backend-specific noise pick different clusters."""
    import jax

    rng = np.random.default_rng(13)
    base = np.concatenate([
        rng.normal((0, 0), 0.2, size=(25, 2)),
        rng.normal((3, 3), 0.2, size=(25, 2)),
    ]).astype(np.float32)
    x = np.repeat(base, 6, axis=0)               # 300 points, 6-way duplicates
    kmax = 8
    backends = ["ref", "jnp"]
    backends.append("pallas" if jax.default_backend() == "tpu" else "pallas_interpret")
    fits = {b: MultiHDBSCAN(kmax=kmax, backend=b).fit(x) for b in backends}
    for mpts in range(2, kmax + 1):
        ref_labels = fits["ref"].labels_for(mpts)
        for b in backends[1:]:
            np.testing.assert_array_equal(
                ref_labels, fits[b].labels_for(mpts), err_msg=f"{b} mpts={mpts}"
            )
