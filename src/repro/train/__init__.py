from . import checkpoint, data, metrics, optim, step

__all__ = ["checkpoint", "data", "metrics", "optim", "step"]
