"""Dual-tree Borůvka candidate generation — the large-n tier (ISSUE 6).

The WSPD/SBCN candidate stage (core.sbcn) is exact but O(n^2)-flavored: the
number of well-separated pairs is linear, but dense regions produce pair
tiles whose total area grows superlinearly, which capped routine benchmarks
at n≈4000.  This module replaces the *candidate generation* for large n with
two dual-tree traversals over the same fair-split tree (core.wspd, built
with ``leaf_size > 1`` so recursion bottoms out in batched leaf tiles):

  ``knn_candidates``   — dual-tree kNN candidate search.  Host-side f64
                         control plane that returns, per point, a superset
                         of its ``k_eff`` nearest neighbours; the *exact*
                         distances and final top-k come from the same device
                         ``_refine_knn`` program every other backend uses,
                         so kNN output is bit-identical to the small-n tier.
  ``dualtree_graph``   — margin-collecting dual-tree Borůvka under the
                         mutual-reachability metric at mpts=kmax.  Produces
                         a candidate edge set S such that kNN ∪ S contains
                         an MST of the complete mrd_kmax graph; edge
                         weights are then computed ON DEVICE by the same
                         ``mrd`` programs as the small-n tier.

Why kNN ∪ (an MST under mrd_kmax) suffices for the WHOLE mpts range
(the CORE-SG containment argument; docs/architecture.md "Dual-tree
Borůvka" has the full derivation): for any cut and any mpts <= kmax, take a
minimum-w_mpts crossing edge e=(a,b).  Either d(a,b) <= c_kmax(a) (or the
symmetric case) — then b is in a's kmax-NN list and e is a kNN-graph edge —
or d(a,b) strictly exceeds both core distances, in which case
w_kmax(e) = d(a,b) = w_mpts(e); since w_kmax >= w_mpts pointwise, e is also
a minimum-w_kmax crossing edge, so MST_kmax contains a crossing edge f* with
w_kmax(f*) = w_kmax(e), hence w_mpts(f*) <= w_mpts(e): f* is a minimum
crossing edge under mpts too.  Every cut therefore has a minimum crossing
edge inside kNN ∪ MST_kmax, which makes it a valid MST candidate graph for
every mpts — exactly the property the RNG^kmax supergraph provides on the
small-n tier, at a fraction of the edges.

Exactness discipline (the pruning-bug defense the ISSUE demands):

  * Host traversals run in f64 and NEVER produce a distance that reaches
    results — they only select candidate STRUCTURE (index sets).  All
    distances/weights that downstream stages consume are computed by the
    same f32 device programs as the oracle path.
  * Pruning and emission use a relative margin (``margin``, default from
    ``Plan.dualtree_margin``) on the f64 bounds, so f32-vs-f64 ordering
    disagreements near ties can only ADD candidates, never drop one.
  * Per point we keep the best AND the runner-up outgoing edge within the
    margin of its component's bound, so an f32 tie-break that prefers a
    different minimum edge still finds it in the candidate set.

Everything here is level-synchronous vectorized numpy (the wspd_pairs
idiom): worklists are arrays, node statistics are reduceat/segment sweeps,
leaf-leaf interactions evaluate as batched (P, L, L) tiles.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import wspd as wspd_mod

# leaf tile evaluation is chunked so the (P, L, L) scratch stays bounded
_TILE_BUDGET = 1 << 22


# ---------------------------------------------------------------------------
# Tree index: levels, parents, leaf partition, node statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TreeIndex:
    """A fair-split tree plus the traversal scaffolding both searches share."""

    tree: wspd_mod.FairSplitTree
    parent: np.ndarray            # (n_nodes,) parent id, -1 for root
    levels: list                  # node ids per depth, root first
    internal_rev: list            # internal node ids per depth, DEEPEST first
    leaf_order: np.ndarray        # leaf ids sorted by perm range start
    leaf_starts: np.ndarray       # (n_leaves,) — a partition of [0, n)
    leaf_max: int                 # max leaf size (tile width)
    size: np.ndarray              # (n_nodes,) point counts
    bbox_lo: np.ndarray           # (n_nodes, d) per-node coordinate minima
    bbox_hi: np.ndarray           # (n_nodes, d) per-node coordinate maxima


def build_index(
    x: np.ndarray, cd_kmax: np.ndarray, *, leaf_size: int
) -> TreeIndex:
    tree = wspd_mod.build_fair_split_tree(x, cd_kmax, leaf_size=leaf_size)
    left, right = tree.left, tree.right
    parent = np.full(tree.n_nodes, -1, np.int64)
    internal = np.nonzero(left != -1)[0]
    parent[left[internal]] = internal
    parent[right[internal]] = internal

    levels = []
    cur = np.array([0], np.int64)
    while len(cur):
        levels.append(cur)
        isn = cur[left[cur] != -1]
        if not len(isn):
            break
        cur = np.concatenate([left[isn], right[isn]])
    internal_rev = [
        lev[left[lev] != -1]
        for lev in reversed(levels)
        if (left[lev] != -1).any()
    ]

    leaves = np.nonzero(left == -1)[0]
    leaf_order = leaves[np.argsort(tree.start[leaves])]
    size = tree.end - tree.start
    ix = TreeIndex(
        tree=tree,
        parent=parent,
        levels=levels,
        internal_rev=internal_rev,
        leaf_order=leaf_order,
        leaf_starts=tree.start[leaf_order],
        leaf_max=int(size[leaves].max()),
        size=size,
        bbox_lo=np.empty(0),
        bbox_hi=np.empty(0),
    )
    # per-node bboxes: per-dim clamp bounds are far tighter than the
    # circumscribed balls in higher d (a ball bound degrades as sqrt(d))
    d = x.shape[1]
    ix.bbox_lo = np.stack(
        [node_agg(ix, x[:, j], np.minimum) for j in range(d)], axis=1
    )
    ix.bbox_hi = np.stack(
        [node_agg(ix, x[:, j], np.maximum) for j in range(d)], axis=1
    )
    return ix


def node_agg(ix: TreeIndex, vals: np.ndarray, op) -> np.ndarray:
    """Per-node aggregate of a per-POINT array (op = np.minimum/np.maximum).

    One reduceat over the leaf partition (leaves tile perm contiguously) and
    a bottom-up child sweep: O(n + n_nodes) per call, cheap enough to
    recompute every traversal wave as bounds tighten.
    """
    vp = vals[ix.tree.perm]
    agg = np.empty(ix.tree.n_nodes, vp.dtype)
    agg[ix.leaf_order] = op.reduceat(vp, ix.leaf_starts)
    for nodes in ix.internal_rev:
        agg[nodes] = op(agg[ix.tree.left[nodes]], agg[ix.tree.right[nodes]])
    return agg


def node_pair_lb2(ix: TreeIndex, U: np.ndarray, V: np.ndarray) -> np.ndarray:
    """Squared lower bound on min pairwise distance between two nodes' points.

    Max of two sound bounds, which dominate in different regimes:

      * ball:  (max(0, ||c_U - c_V|| - r_U - r_V))^2 — wins on DIAGONAL
        separation, where shallow fair-split cells still overlap per-axis
        (the common case in moderate d, where depth/d < 2 and every bbox
        interval spans a large slice of the data range);
      * bbox:  sum of squared per-dimension interval gaps — wins on
        axis-aligned separation, where the circumscribed-ball radii grow
        like sqrt(d) times the side length and the ball bound collapses.
    """
    tree = ix.tree
    diff = tree.center[U] - tree.center[V]
    dc = np.sqrt(np.einsum("ij,ij->i", diff, diff))
    lb = np.maximum(0.0, dc - tree.radius[U] - tree.radius[V])
    gap = np.maximum(
        ix.bbox_lo[U] - ix.bbox_hi[V], ix.bbox_lo[V] - ix.bbox_hi[U]
    )
    gap = np.maximum(gap, 0.0)
    return np.maximum(lb * lb, np.einsum("ij,ij->i", gap, gap))


def node_pair_ub2(ix: TreeIndex, U: np.ndarray, V: np.ndarray) -> np.ndarray:
    """Squared upper bound on min pairwise distance: min of the ball bound
    (center gap + both radii) and the per-dim bbox span — both bound the
    MAX pairwise distance, hence also the min."""
    tree = ix.tree
    diff = tree.center[U] - tree.center[V]
    dc = np.sqrt(np.einsum("ij,ij->i", diff, diff))
    ub = dc + tree.radius[U] + tree.radius[V]
    span = np.maximum(
        ix.bbox_hi[U] - ix.bbox_lo[V], ix.bbox_hi[V] - ix.bbox_lo[U]
    )
    return np.minimum(ub * ub, np.einsum("ij,ij->i", span, span))


def _pairs_below(
    ix: TreeIndex, U: np.ndarray, V: np.ndarray, thresh: np.ndarray
) -> np.ndarray:
    """Boolean keep-mask: pair i survives iff ``node_pair_lb2 <= thresh[i]``.

    Phased cheapest-first evaluation of the same combined bound as
    ``node_pair_lb2`` — the ball test runs sqrt-free on all pairs
    (``dc2 <= (sqrt(thresh) + r_U + r_V)^2``), the bbox gathers and gap
    einsum run only on ball survivors.  In the hot traversal waves the
    bound arithmetic itself is a top-two cost, so evaluating the second
    bound on the (much smaller) survivor set matters.
    """
    tree = ix.tree
    keep = np.zeros(len(U), bool)
    diff = tree.center[U] - tree.center[V]
    dc2 = np.einsum("ij,ij->i", diff, diff)
    lim = np.sqrt(thresh) + tree.radius[U] + tree.radius[V]
    s = np.nonzero(dc2 <= lim * lim)[0]
    if not len(s):
        return keep
    Us, Vs = U[s], V[s]
    gap = np.maximum(
        ix.bbox_lo[Us] - ix.bbox_hi[Vs], ix.bbox_lo[Vs] - ix.bbox_hi[Us]
    )
    np.maximum(gap, 0.0, out=gap)
    keep[s[np.einsum("ij,ij->i", gap, gap) <= thresh[s]]] = True
    return keep


# ---------------------------------------------------------------------------
# Shared vectorized helpers
# ---------------------------------------------------------------------------


def _run_rank(sorted_ids: np.ndarray) -> np.ndarray:
    """Rank within equal-value runs of an already-sorted id array."""
    idx = np.arange(len(sorted_ids))
    new = np.concatenate([[True], sorted_ids[1:] != sorted_ids[:-1]])
    return idx - np.maximum.accumulate(np.where(new, idx, 0))


def _merge_topk(
    bestd: np.ndarray, besti: np.ndarray, q: np.ndarray, r: np.ndarray, d2: np.ndarray
) -> None:
    """Merge (q, r, d2) contributions into running per-row top-k, in place.

    Deduplicates (q, r) pairs (traversal and priming windows can both visit
    a pair — a duplicate occupying two slots would shrink the row's kth
    bound below the true kth distance and over-prune).  Ties sort by (d2, r)
    so the kept set is deterministic.
    """
    if len(q) == 0:
        return
    k_eff = bestd.shape[1]
    uq, inv = np.unique(q, return_inverse=True)
    cur_r = besti[uq].ravel()
    cur_d = bestd[uq].ravel()
    cur_row = np.repeat(np.arange(len(uq)), k_eff)
    valid = cur_r >= 0
    row = np.concatenate([cur_row[valid], inv])
    rr = np.concatenate([cur_r[valid], r])
    dd = np.concatenate([cur_d[valid], d2])
    # dedup (row, r), keep min d2
    o = np.lexsort((dd, rr, row))
    row, rr, dd = row[o], rr[o], dd[o]
    first = np.concatenate(
        [[True], (row[1:] != row[:-1]) | (rr[1:] != rr[:-1])]
    )
    row, rr, dd = row[first], rr[first], dd[first]
    # per-row top-k by (d2, r)
    o2 = np.lexsort((rr, dd, row))
    row, rr, dd = row[o2], rr[o2], dd[o2]
    rank = _run_rank(row)
    keep = rank < k_eff
    row, rr, dd, rank = row[keep], rr[keep], dd[keep], rank[keep]
    bestd[uq] = np.inf
    besti[uq] = -1
    bestd[uq[row], rank] = dd
    besti[uq[row], rank] = rr


def _leaf_points(ix: TreeIndex, nodes: np.ndarray) -> np.ndarray:
    """(P, leaf_max) point ids of each leaf node, -1 padded."""
    tree = ix.tree
    s, e = tree.start[nodes], tree.end[nodes]
    pos = s[:, None] + np.arange(ix.leaf_max)[None, :]
    valid = pos < e[:, None]
    ids = tree.perm[np.where(valid, pos, 0)]
    return np.where(valid, ids, -1)


def _tile_d2(x: np.ndarray, qid: np.ndarray, rid: np.ndarray) -> np.ndarray:
    """(P, L, L) f64 squared distances; inf at padding and self pairs.

    Matmul form is fine here: these distances are advisory (bounds and
    candidate selection under a margin); every distance that reaches results
    is recomputed by the exact device programs.
    """
    xq = x[np.where(qid >= 0, qid, 0)]
    xr = x[np.where(rid >= 0, rid, 0)]
    qn = np.einsum("pld,pld->pl", xq, xq)
    rn = np.einsum("pld,pld->pl", xr, xr)
    d2 = qn[:, :, None] + rn[:, None, :] - 2.0 * np.einsum("pld,pmd->plm", xq, xr)
    np.maximum(d2, 0.0, out=d2)
    bad = (
        (qid[:, :, None] < 0)
        | (rid[:, None, :] < 0)
        | (qid[:, :, None] == rid[:, None, :])
    )
    d2[bad] = np.inf
    return d2


def _rows_d2(x: np.ndarray, q: np.ndarray, r: np.ndarray) -> np.ndarray:
    """(R, C) f64 squared distances between x[q[i]] and x[r[i, j]]."""
    xq = x[q]
    xr = x[r]
    qn = np.einsum("rd,rd->r", xq, xq)
    rn = np.einsum("rcd,rcd->rc", xr, xr)
    d2 = qn[:, None] + rn - 2.0 * np.einsum("rd,rcd->rc", xq, xr)
    np.maximum(d2, 0.0, out=d2)
    return d2


def _split_pairs(ix: TreeIndex, U, V, sp):
    """One splitting step: self pairs expand to (l,l),(r,r),(l,r); non-self
    pairs split the larger-radius side (never a leaf).  Returns the next
    worklist.  Every unordered node pair is generated at most once."""
    tree = ix.tree
    left, right, radius = tree.left, tree.right, tree.radius
    si = U[sp]
    sl, sr = left[si], right[si]
    Un, Vn = U[~sp], V[~sp]
    can_u = left[Un] != -1
    can_v = left[Vn] != -1
    ru, rv = radius[Un], radius[Vn]
    su = (ru > rv) | ((ru == rv) & (ix.size[Un] >= ix.size[Vn]))
    su = np.where(can_u & can_v, su, can_u)
    Us, Vs = Un[su], Vn[su]
    Uo, Vo = Un[~su], Vn[~su]
    nU = np.concatenate([sl, sr, sl, left[Us], right[Us], Uo, Uo])
    nV = np.concatenate([sl, sr, sr, Vs, Vs, left[Vo], right[Vo]])
    return nU, nV


# ---------------------------------------------------------------------------
# Dual-tree kNN candidate search
# ---------------------------------------------------------------------------


def knn_candidates(
    x: np.ndarray,
    k_eff: int,
    *,
    leaf_size: int = 32,
    margin: float = 1e-5,
) -> np.ndarray:
    """Per-point candidate neighbour sets via dual-tree search.

    Returns (n, k_eff) int32 neighbour ids (no self, -1 padded only when
    n - 1 < k_eff), each row ordered by (f32-cast distance, id) so the
    device refine pass's top-k tie-breaks match the other backends'.

    The search maintains per-point kth-candidate bounds; a node pair (U, V)
    is pruned when its distance lower bound exceeds ``(1 + margin) * B``
    with B = max over the pair's points of their kth bound — pruned pairs
    provably contain no candidate-improving point (property-tested).
    """
    x = np.ascontiguousarray(np.asarray(x, np.float64))
    n = x.shape[0]
    if n < 2:
        return np.full((n, k_eff), -1, np.int32)
    k_eff = min(k_eff, n - 1)
    ix = build_index(x, np.zeros(n), leaf_size=leaf_size)
    tree = ix.tree

    bestd = np.full((n, k_eff), np.inf)
    besti = np.full((n, k_eff), -1, np.int64)

    # ---- prime the bounds: perm-order sliding windows ---------------------
    # The tree permutation groups spatially-near points, so a width-W window
    # around each perm position yields finite (and usually tight) kth bounds
    # before the traversal starts — without it the first waves can't prune.
    W = min(n, 2 * k_eff + 2)
    starts = np.clip(np.arange(n) - W // 2, 0, n - W)
    perm = tree.perm
    chunk = max(1, _TILE_BUDGET // (W * x.shape[1]))
    for c0 in range(0, n, chunk):
        c1 = min(n, c0 + chunk)
        q = perm[c0:c1]
        r = perm[starts[c0:c1, None] + np.arange(W)[None, :]]
        d2 = _rows_d2(x, q, r)
        qf = np.repeat(q, W)
        rf = r.ravel()
        df = d2.ravel()
        ok = qf != rf
        _merge_topk(bestd, besti, qf[ok], rf[ok], df[ok])

    # ---- NN-descent passes: tighten bounds toward exact -------------------
    # The traversal's prune volume scales like (bound/true_kth)^d — in
    # moderate d a loose warm start inflates the visited node pairs by
    # orders of magnitude.  A couple of neighbours-of-neighbours passes
    # (NN-descent) drive the kth bounds near-exact for a few n*k^2 d2
    # evaluations, after which the traversal does little beyond proving
    # no candidate was missed.
    for _ in range(2):
        nb = np.where(besti >= 0, besti, 0)
        kk = nb.shape[1]
        cand2 = nb[nb.ravel()].reshape(n, kk * kk)
        chunk2 = max(1, _TILE_BUDGET // (kk * kk * x.shape[1]))
        improved = 0
        for c0 in range(0, n, chunk2):
            c1 = min(n, c0 + chunk2)
            q = np.arange(c0, c1)
            r = cand2[c0:c1]
            d2 = _rows_d2(x, q, r)
            qf = np.repeat(q, r.shape[1])
            rf = r.ravel()
            df = d2.ravel()
            ok = (qf != rf) & (df < bestd[qf, -1])
            improved += int(ok.sum())
            _merge_topk(bestd, besti, qf[ok], rf[ok], df[ok])
        if improved == 0:
            break

    # ---- level-synchronous dual-tree traversal ----------------------------
    U = np.array([0], np.int64)
    V = np.array([0], np.int64)
    left = tree.left
    tile_chunk = max(1, _TILE_BUDGET // max(1, ix.leaf_max**2))
    while len(U):
        B = node_agg(ix, bestd[:, -1], np.maximum)
        sp = U == V
        keep = sp.copy()
        ns = np.nonzero(~sp)[0]
        if len(ns):
            Un, Vn = U[ns], V[ns]
            thresh = np.maximum(B[Un], B[Vn]) * (1.0 + margin)
            keep[ns[_pairs_below(ix, Un, Vn, thresh)]] = True
        U, V, sp = U[keep], V[keep], sp[keep]
        if not len(U):
            break
        leaf = (left[U] == -1) & (left[V] == -1)
        lu, lv = U[leaf], V[leaf]
        for c0 in range(0, len(lu), tile_chunk):
            cu, cv = lu[c0 : c0 + tile_chunk], lv[c0 : c0 + tile_chunk]
            qid = _leaf_points(ix, cu)
            rid = _leaf_points(ix, cv)
            d2 = _tile_d2(x, qid, rid)
            P, L = qid.shape
            qf = np.broadcast_to(qid[:, :, None], (P, L, L)).ravel()
            rf = np.broadcast_to(rid[:, None, :], (P, L, L)).ravel()
            df = d2.ravel()
            # both directions; dedup in the merge handles self pairs
            qf2 = np.concatenate([qf, rf])
            rf2 = np.concatenate([rf, qf])
            df2 = np.concatenate([df, df])
            # drop entries that cannot enter the top-k (strictly worse than
            # the row's current kth bound; ties kept)
            ok = np.isfinite(df2)
            ok &= df2 <= bestd[np.where(ok, qf2, 0), -1] + np.where(ok, 0, np.inf)
            _merge_topk(bestd, besti, qf2[ok], rf2[ok], df2[ok])
        U, V, sp = U[~leaf], V[~leaf], sp[~leaf]
        if not len(U):
            break
        U, V = _split_pairs(ix, U, V, sp)

    # Order rows by (f32-cast distance, id): the device refine recomputes
    # exact f32 distances and takes a stable top-k, so candidate ORDER is
    # what breaks exact-tie ranks — ascending id matches the other backends.
    d32 = bestd.astype(np.float32)
    rows = np.repeat(np.arange(n), k_eff)
    o = np.lexsort((besti.ravel(), d32.ravel(), rows))
    return besti.ravel()[o].reshape(n, k_eff).astype(np.int32)


# ---------------------------------------------------------------------------
# Margin-collecting dual-tree Borůvka under mrd_kmax
# ---------------------------------------------------------------------------


def _merge_components(comp: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Union the components joined by (lo, hi) edges; labels are min point
    ids (hook to roots + pointer jumping, all vectorized)."""
    lab = comp.copy()
    if len(lo) == 0:
        return lab
    for _ in range(64):
        before = lab.copy()
        m = np.minimum(lab[lo], lab[hi])
        np.minimum.at(lab, before[lo], m)
        np.minimum.at(lab, before[hi], m)
        while True:
            nl = lab[lab]
            if np.array_equal(nl, lab):
                break
            lab = nl
        if np.array_equal(lab, before):
            return lab
    raise RuntimeError("dualtree: component merge did not converge")


def boruvka_tree_edges(
    ix: TreeIndex,
    x: np.ndarray,
    cd2k: np.ndarray,
    knn_d2: np.ndarray,
    knn_idx: np.ndarray,
    *,
    margin: float = 1e-5,
    max_rounds: int = 64,
) -> tuple[np.ndarray, dict]:
    """Candidate MST edges under mrd_kmax via dual-tree Borůvka.

    Returns ((m, 2) int64 lo<hi edges, stats).  Per round, per component,
    the edge set contains every point's best and runner-up outgoing edge
    whose f64 weight is within ``(1 + margin)`` of the component's minimum —
    so kNN ∪ result contains a minimum outgoing edge per component under
    the DEVICE f32 ordering too, which is what makes the downstream f32
    Borůvka over the candidate graph produce a true complete-graph MST.

    Contraction is stricter than emission: components merge only along
    their (w, lo, hi)-lexicographic-minimum outgoing edge, i.e. vanilla
    Borůvka under a distinct total order, so the union of contraction
    edges is itself a true MST under mrd_kmax and every cut the exact
    downstream pass needs has been examined by some round.
    """
    n = x.shape[0]
    tree = ix.tree
    left = tree.left
    kd = knn_d2.astype(np.float64)
    ki = knn_idx.astype(np.int64)
    rows_k = np.arange(n)[:, None]
    min_cd2 = node_agg(ix, cd2k, np.minimum)
    tile_chunk = max(1, _TILE_BUDGET // max(1, ix.leaf_max**2))

    comp = np.arange(n)
    out_lo: list[np.ndarray] = []
    out_hi: list[np.ndarray] = []
    stats = {"n_rounds": 0, "n_leaf_tiles": 0}
    for _round in range(max_rounds):
        n_comp = len(np.unique(comp))
        if n_comp == 1:
            break
        stats["n_rounds"] += 1

        # -- per-point best/runner-up init from the kNN lists --------------
        mr = np.maximum(kd, np.maximum(cd2k[:, None], cd2k[ki]))
        mr[comp[:, None] == comp[ki]] = np.inf
        bw = np.full((n, 2), np.inf)
        bi = np.full((n, 2), -1, np.int64)
        take = min(2, kd.shape[1])
        o = np.argsort(mr, axis=1, kind="stable")[:, :take]
        cand_w = np.take_along_axis(mr, o, axis=1)
        cand_i = np.take_along_axis(ki, o, axis=1)
        fin = np.isfinite(cand_w)
        bw[:, :take][fin] = cand_w[fin]
        bi[:, :take][fin] = cand_i[fin]

        # components are static within a round: uniform-component node ids
        umin = node_agg(ix, comp, np.minimum)
        umax = node_agg(ix, comp, np.maximum)
        ucomp = np.where(umin == umax, umin, -1)

        # -- traversal: improve per-point bests under mrd_kmax --------------
        U = np.array([0], np.int64)
        V = np.array([0], np.int64)
        while len(U):
            bwc = np.full(n, np.inf)
            np.minimum.at(bwc, comp, bw[:, 0])
            B = node_agg(ix, bwc[comp], np.maximum)
            sp = U == V
            same = (ucomp[U] >= 0) & (ucomp[U] == ucomp[V])
            thresh = np.maximum(B[U], B[V]) * (1.0 + margin)
            # self pairs have lb2 = 0 but still carry the core-distance
            # floor, so the bound check applies to them too
            alive = ~same & (np.maximum(min_cd2[U], min_cd2[V]) <= thresh)
            keep = alive & sp
            ns = np.nonzero(alive & ~sp)[0]
            if len(ns):
                keep[ns[_pairs_below(ix, U[ns], V[ns], thresh[ns])]] = True
            U, V, sp = U[keep], V[keep], sp[keep]
            if not len(U):
                break
            leaf = (left[U] == -1) & (left[V] == -1)
            lu, lv = U[leaf], V[leaf]
            for c0 in range(0, len(lu), tile_chunk):
                cu = lu[c0 : c0 + tile_chunk]
                cv = lv[c0 : c0 + tile_chunk]
                stats["n_leaf_tiles"] += len(cu)
                qid = _leaf_points(ix, cu)
                rid = _leaf_points(ix, cv)
                t = _tile_d2(x, qid, rid)
                qs = np.where(qid >= 0, qid, 0)
                rs = np.where(rid >= 0, rid, 0)
                np.maximum(t, cd2k[qs][:, :, None], out=t)
                np.maximum(t, cd2k[rs][:, None, :], out=t)
                t[comp[qs][:, :, None] == comp[rs][:, None, :]] = np.inf
                P, L = qid.shape
                qf = np.broadcast_to(qid[:, :, None], (P, L, L)).ravel()
                rf = np.broadcast_to(rid[:, None, :], (P, L, L)).ravel()
                tf = t.ravel()
                qf2 = np.concatenate([qf, rf])
                rf2 = np.concatenate([rf, qf])
                tf2 = np.concatenate([tf, tf])
                ok = np.isfinite(tf2)
                _merge_topk(bw, bi, qf2[ok], rf2[ok], tf2[ok])
            U, V, sp = U[~leaf], V[~leaf], sp[~leaf]
            if not len(U):
                break
            U, V = _split_pairs(ix, U, V, sp)

        # -- margin emission + contraction ----------------------------------
        bwc = np.full(n, np.inf)
        np.minimum.at(bwc, comp, bw[:, 0])
        thresh = bwc[comp] * (1.0 + margin)
        e_lo = []
        e_hi = []
        for col in (0, 1):
            sel = np.isfinite(bw[:, col]) & (bw[:, col] <= thresh)
            p = np.nonzero(sel)[0]
            q = bi[p, col]
            e_lo.append(np.minimum(p, q))
            e_hi.append(np.maximum(p, q))
        lo = np.concatenate(e_lo)
        hi = np.concatenate(e_hi)
        out_lo.append(lo)
        out_hi.append(hi)

        # -- contraction: ONE edge per component — its minimum outgoing edge
        # under the total order (w, lo, hi).  The margin/runner-up edges
        # above are candidates only: contracting along a non-minimum (or
        # inconsistently tie-broken) edge coarsens later rounds, and a cut
        # inside a coarsened component is never examined again — its true
        # minimum crossing edge would be silently dropped.  Distinct total
        # order keys make this vanilla Borůvka: the union of contraction
        # edges over rounds is exactly one true MST under mrd_kmax.
        # (Per-point slot 0 suffices: _merge_topk ranks ties by (d2, r), and
        # for a fixed point, minimizing the neighbour id also minimizes the
        # (lo, hi) edge key, so the component's lexicographic-minimum
        # outgoing edge is some member point's slot-0 edge.)
        pc = np.nonzero(np.isfinite(bw[:, 0]))[0]
        qc = bi[pc, 0]
        wc = bw[pc, 0]
        lo_c = np.minimum(pc, qc)
        hi_c = np.maximum(pc, qc)
        cpc = comp[pc]
        oc = np.lexsort((hi_c, lo_c, wc, cpc))
        first_c = np.concatenate([[True], cpc[oc][1:] != cpc[oc][:-1]])
        sel = oc[first_c]
        comp = _merge_components(comp, lo_c[sel], hi_c[sel])
        if len(np.unique(comp)) >= n_comp:
            raise RuntimeError(
                f"dualtree Borůvka made no progress at round {_round} "
                f"({n_comp} components) — traversal bound bug"
            )
    else:
        raise RuntimeError(
            f"dualtree Borůvka did not converge in {max_rounds} rounds"
        )

    lo = np.concatenate(out_lo) if out_lo else np.zeros(0, np.int64)
    hi = np.concatenate(out_hi) if out_hi else np.zeros(0, np.int64)
    keys = np.unique(lo * n + hi)
    edges = np.stack([keys // n, keys % n], axis=1)
    stats["m_tree_edges"] = int(len(edges))
    return edges, stats


def candidate_edges(
    x_host: np.ndarray,
    knn_d2_host: np.ndarray,
    knn_idx_host: np.ndarray,
    *,
    leaf_size: int = 32,
    margin: float = 1e-5,
) -> tuple[np.ndarray, dict]:
    """kNN-graph edges ∪ dual-tree Borůvka edges, sorted by (lo, hi).

    The host half of ``dualtree_graph`` (core.rng wires the device half:
    exact edge weights + the ledgered materialization).
    """
    x = np.ascontiguousarray(np.asarray(x_host, np.float64))
    n = x.shape[0]
    cd2k = knn_d2_host[:, -1].astype(np.float64)
    ix = build_index(x, np.sqrt(cd2k), leaf_size=leaf_size)
    tree_edges, stats = boruvka_tree_edges(
        ix, x, cd2k, knn_d2_host, knn_idx_host, margin=margin
    )
    p = np.repeat(np.arange(n), knn_idx_host.shape[1])
    q = knn_idx_host.astype(np.int64).ravel()
    knn_keys = np.minimum(p, q) * n + np.maximum(p, q)
    tree_keys = tree_edges[:, 0] * n + tree_edges[:, 1]
    keys = np.unique(np.concatenate([knn_keys, tree_keys]))
    edges = np.stack([keys // n, keys % n], axis=1)
    stats["m_knn_edges"] = int(len(np.unique(knn_keys)))
    stats["m_candidates"] = int(len(edges))
    return edges, stats
