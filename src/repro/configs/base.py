"""Model/config system: one frozen dataclass per architecture + registry.

Every assigned architecture is a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``) and is selectable by ``--arch <id>`` in every
launcher.  ``reduced()`` derives the CPU smoke-test configuration (same
family, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = [
    "gemma3_4b",
    "qwen2_5_14b",
    "qwen2_1_5b",
    "starcoder2_3b",
    "mamba2_780m",
    "recurrentgemma_2b",
    "deepseek_v2_lite_16b",
    "kimi_k2_1t_a32b",
    "llava_next_34b",
    "seamless_m4t_large_v2",
]

# (name, seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | vlm | audio
    arch: str                       # transformer | mamba2 | griffin | encdec
    vocab: int
    d_model: int
    n_layers: int
    # attention
    n_heads: int = 0
    n_kv: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None   # gemma3: global layers differ
    window: int = 0                             # sliding window (0 = full)
    window_period: int = 0                      # gemma3: every `period`-th layer global
    logit_softcap: float = 0.0
    # mlp
    d_ff: int = 0
    act: str = "swiglu"                         # swiglu | geglu | gelu
    mlp_bias: bool = False
    # embeddings
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # MLA
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # mamba2 (SSD)
    d_state: int = 0
    expand: int = 2
    ssm_head: int = 64
    ssd_chunk: int = 256
    d_conv: int = 4
    # griffin (RG-LRU)
    block_pattern: tuple = ()                   # e.g. ("R", "R", "A")
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    dec_seq_frac: float = 0.25                  # decoder len = frac * seq_len
    # frontend stubs (vlm / audio): precomputed embeddings enter the stream
    frontend: Optional[str] = None              # patches | frames
    frontend_dim: int = 0
    frontend_tokens_4k: int = 0                 # patch positions inside train_4k
    # numerics / training
    dtype: str = "bfloat16"                      # compute dtype
    param_dtype: str = "float32"                 # master weights
    grad_accum_dtype: str = "float32"            # microbatch accumulation
    remat: bool = True
    microbatch: int = 1                          # grad-accum steps per train_step
    optimizer_state_dtype: str = "float32"       # float32 | bfloat16 | int8
    xent_chunk: int = 512                        # seq-chunked cross entropy
    # shape-cell policy
    run_long_500k: bool = False
    skip_note: str = ""

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded so the vocab dim shards cleanly over
        the model axis (MaxText-style padding; logits rows beyond vocab are
        never referenced by the loss)."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink(v, cap):
            return min(v, cap) if v else v

        return dataclasses.replace(
            self,
            vocab=min(self.vocab, 512),
            d_model=64,
            n_layers=min(self.n_layers, 4) or 4,
            n_heads=shrink(self.n_heads, 4),
            n_kv=shrink(self.n_kv, 2),
            d_head=shrink(self.d_head, 16),
            d_ff=shrink(self.d_ff, 128),
            n_experts=shrink(self.n_experts, 8),
            n_shared=shrink(self.n_shared, 1),
            top_k=shrink(self.top_k, 2),
            d_ff_expert=shrink(self.d_ff_expert, 32),
            kv_lora=shrink(self.kv_lora, 32),
            qk_nope=shrink(self.qk_nope, 16),
            qk_rope=shrink(self.qk_rope, 8),
            v_head=shrink(self.v_head, 16),
            d_state=shrink(self.d_state, 16),
            ssm_head=shrink(self.ssm_head, 16),
            ssd_chunk=min(self.ssd_chunk, 32) if self.ssd_chunk else 0,
            n_enc_layers=shrink(self.n_enc_layers, 2),
            n_dec_layers=shrink(self.n_dec_layers, 2),
            frontend_dim=shrink(self.frontend_dim, 48),
            frontend_tokens_4k=shrink(self.frontend_tokens_4k, 16),
            window=shrink(self.window, 8),
            xent_chunk=32,
            microbatch=1,
            dtype="float32",
            param_dtype="float32",
            grad_accum_dtype="float32",
        )


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
