"""Logical-axis sharding rules (GSPMD-style, in the spirit of maxtext).

Param pytrees carry *specs*: a tuple of logical axis names per array dim
(see models/layers.py).  A *rules* dict maps logical names to mesh axes;
``resolve_rules`` filters it against the actual mesh so the same model code
runs on a laptop (1 device, everything replicated) and a pod (16x16).

``constrain`` is the single choke point models call on activations.  Outside
an ``activation_context`` it is the identity, which is what keeps every
single-device test mesh-free; inside one it applies
``with_sharding_constraint`` under the context's mesh and rules.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Logical-name defaults.  Params: shard the "wide" dims over model; keep the
# embedding dim replicated (row-parallel activations).  Activations: batch
# over data, heads/ff/vocab over model.
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    # param dims
    "embed": None,
    "ff": "model",
    "heads_dim": "model",
    "kv_dim": "model",
    "vocab": "model",
    "experts": "model",
    "lru": "model",
    "inner": "model",
    "inner_all": "model",
    # activation dims
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_ff": "model",
    "act_heads": "model",
    "act_vocab": "model",
    "act_experts": "model",
}


def _filter_axes(v, mesh):
    """Drop mesh axes that don't exist (or are trivial) on this mesh."""
    if v is None:
        return None
    if isinstance(v, (list, tuple)):
        kept = tuple(a for a in v if a in mesh.shape and mesh.shape[a] > 1)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return v if v in mesh.shape and mesh.shape[v] > 1 else None


def resolve_rules(mesh, override=None) -> dict:
    """DEFAULT_RULES (+ overrides, e.g. from --rules JSON) valid on ``mesh``."""
    rules = dict(DEFAULT_RULES)
    if override:
        rules.update(override)
    return {k: _filter_axes(v, mesh) for k, v in rules.items()}


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and all(s is None or isinstance(s, str) for s in x)


def pspec_for(spec, rules) -> P:
    """One spec tuple -> PartitionSpec under resolved rules."""
    return P(*(rules.get(name) if name is not None else None for name in spec))


def tree_shardings(specs, mesh, rules):
    """Spec pytree (mirrors params) -> NamedSharding pytree."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, pspec_for(spec, rules)), specs,
        is_leaf=_is_spec,
    )


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def activation_context(mesh, rules):
    """Within this context, ``constrain`` applies sharding constraints."""
    prev = getattr(_CTX, "value", None)
    _CTX.value = (mesh, rules)
    try:
        yield
    finally:
        _CTX.value = prev


def constrain(x, names):
    """Constrain activation ``x`` to the logical axes ``names`` (or no-op)."""
    ctx = getattr(_CTX, "value", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(names):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, pspec_for(names, rules))
    )


# ---------------------------------------------------------------------------
# launcher / dry-run sharding factories
# ---------------------------------------------------------------------------


def _batch_axes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape and mesh.shape[a] > 1)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes:
        return None, 1
    return (axes if len(axes) > 1 else axes[0]), size


def batch_shardings(batch_sds, mesh):
    """Shard the leading (global-batch) dim of every batch leaf over data."""
    axes, size = _batch_axes(mesh)
    repl = NamedSharding(mesh, P())

    def one(sds):
        if axes and getattr(sds, "ndim", 0) >= 1 and sds.shape[0] % size == 0:
            return NamedSharding(mesh, P(axes, *([None] * (sds.ndim - 1))))
        return repl

    return jax.tree.map(one, batch_sds)


def cache_shardings(cache_sds, mesh):
    """Decode caches are laid out (layers, batch, ...): shard dim 1 over data."""
    axes, size = _batch_axes(mesh)
    repl = NamedSharding(mesh, P())

    def one(sds):
        if axes and getattr(sds, "ndim", 0) >= 2 and sds.shape[1] % size == 0:
            return NamedSharding(mesh, P(None, axes, *([None] * (sds.ndim - 2))))
        return repl

    return jax.tree.map(one, cache_sds)


def opt_state_shardings(p_shard, opt_sds, mesh):
    """Optimizer-state shardings mirroring the param shardings.

    Moment trees (adamw m/v, adafactor f) reuse each param's sharding when the
    state leaf has the param's shape; int8-blockwise states shard "q" like the
    param (same shape by design, see optim.q8_compatible) and replicate the
    per-block scales; factored/odd-shaped states and scalars replicate.
    """
    repl = NamedSharding(mesh, P())
    pdef = jax.tree.structure(p_shard)
    pleaves = jax.tree.leaves(p_shard)

    def per_state(tree):
        try:
            subs = pdef.flatten_up_to(tree)
        except ValueError:
            return jax.tree.map(lambda _: repl, tree)
        out = []
        for sh, sub in zip(pleaves, subs):
            if hasattr(sub, "shape"):
                out.append(sh)
            elif isinstance(sub, dict) and set(sub) == {"q", "scale"}:
                out.append({"q": sh, "scale": repl})
            else:
                out.append(jax.tree.map(lambda _: repl, sub))
        return jax.tree.unflatten(pdef, out)

    return {
        k: repl if hasattr(v, "shape") else per_state(v)
        for k, v in opt_sds.items()
    }
