"""Device->host materialization choke point + transfer accounting.

The pipeline contract (docs/architecture.md "Device / host boundaries") is
that bulk device->host syncs happen ONLY at named materialization points:

  ``knn``             — the kNN stage's host view (stored on the result object
                        and consumed by the WSPD control plane).
  ``candidate_count`` — a handful of scalars sizing the static candidate
                        buffers: on the fused-cascade path the (slot, unique,
                        mutual, tie-overflow) counts in ONE sync; on the
                        slot-array path the unique candidate count.
  ``candidate_slots`` — slot-array path only: ONE scalar, the real
                        (non-sentinel) SBCN slot count sizing the scatter
                        compaction ahead of the dedup sort.
  ``stage1_count``    — fused path only: the (certified, open) stage-1
                        survivor counts in ONE sync, sizing the stage-2
                        compactions.
  ``graph``           — RNG^kmax filter-verdict + edge compaction.
  ``lune_exact``      — variant="rng" only: the unresolved-edge subset for the
                        exact lune scan.
  ``mst``             — the final MST compaction, the single sync of the MST
                        stage.
  ``predict``         — the out-of-sample path's single sync: per-row
                        attachment lambdas + neighbours for a query batch
                        (core.predict; the condensed-tree walk is host work).

Everything else stays device-resident.  ``transfer_ledger`` is the test hook
that enforces this: inside the context every ``to_host`` call is recorded as
``(tag, nbytes)`` and jax's transfer guard turns any *implicit* device->host
transfer (e.g. a stray ``np.asarray`` on a jax array) into an error.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np

_LEDGER = threading.local()


def _nbytes(tree) -> int:
    return sum(
        getattr(leaf, "nbytes", 0) for leaf in jax.tree.leaves(tree)
    )


def to_host(tree, tag: str):
    """Explicitly materialize a pytree of device arrays as numpy, ledgered.

    This is the ONLY sanctioned device->host transfer in the clustering
    pipeline; ``tag`` names the materialization point (see module docstring).
    """
    out = jax.device_get(tree)
    ledger = getattr(_LEDGER, "value", None)
    if ledger is not None:
        ledger.append((tag, _nbytes(out)))
    return out


@contextlib.contextmanager
def transfer_ledger(*, guard: bool = True):
    """Record every ``to_host`` as (tag, nbytes); optionally guard implicits.

    With ``guard=True`` (default) the context also arms
    ``jax.transfer_guard_device_to_host("disallow")``, which errors on any
    implicit device->host transfer while leaving the explicit
    ``jax.device_get`` inside ``to_host`` allowed — so the ledger provably
    sees *all* syncs, not just the polite ones.
    """
    prev = getattr(_LEDGER, "value", None)
    ledger: list[tuple[str, int]] = []
    _LEDGER.value = ledger
    try:
        if guard:
            with jax.transfer_guard_device_to_host("disallow"):
                yield ledger
        else:
            yield ledger
    finally:
        _LEDGER.value = prev


def tags(ledger) -> list[str]:
    """The sequence of materialization tags a ledger recorded."""
    return [t for t, _ in ledger]


def count(ledger, tag: str) -> int:
    """How many materializations a ledger recorded under ``tag``."""
    return sum(1 for t, _ in ledger if t == tag)


def ensure_host(x) -> np.ndarray:
    """Host view of ``x`` without triggering the transfer guard for numpy.

    numpy inputs pass through untouched; jax arrays go through ``to_host``
    under the ``input`` tag (only hit when a caller hands device arrays to a
    host-facing entry point).
    """
    if isinstance(x, np.ndarray):
        return x
    if hasattr(x, "__array_namespace__") or type(x).__module__.startswith("jax"):
        return to_host(x, "input")
    return np.asarray(x)
