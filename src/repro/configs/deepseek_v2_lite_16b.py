"""deepseek-v2-lite-16b [moe] — 27L d=2048 16H d_ff(expert)=1408 vocab=102400.

MLA: kv_lora=512, qk_nope=128, qk_rope=64, v=128, no q-compression (V2-Lite).
MoE: 2 shared + 64 routed experts, top-6.  NOTE: the assignment block lists
both "64e" and "2 shared+160 routed"; V2-Lite's published config is 64 routed
=> we implement 64 and record the discrepancy (DESIGN.md §5).
[arXiv:2405.04434; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    arch="transformer",
    vocab=102400,
    d_model=2048,
    n_layers=27,
    n_heads=16,
    n_kv=16,
    d_head=192,                     # qk_nope + qk_rope
    d_ff=0,
    act="swiglu",
    n_experts=64,
    n_shared=2,
    top_k=6,
    d_ff_expert=1408,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head=128,
    rope_theta=10_000.0,
    microbatch=4,
    tie_embeddings=False,
    run_long_500k=False,
    skip_note=(
        "MLA compresses KV memory but attention compute is full-quadratic; "
        "long_500k skipped per task rule"
    ),
)
