"""Training step factory: chunked cross-entropy, microbatch grad-accum,
loss masking, and the (pjit-ready) train_step used by launcher and dry-run.

Memory discipline:
  * cross-entropy is computed in sequence chunks (cfg.xent_chunk) so the
    (B, S, V) logits tensor never materializes — at kimi scale that tensor
    alone would be ~0.5 GB/device.
  * gradients accumulate across `cfg.microbatch` slices inside a lax.scan,
    which also lets XLA overlap the DP gradient reduce-scatter of slice i
    with the compute of slice i+1.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from ..models import get_model
from . import optim as optim_mod


def xent_chunked(logits_fn: Callable, p, cfg, hidden, labels, mask):
    """Mean masked cross-entropy without materializing full logits.

    hidden: (B, S, D); labels, mask: (B, S).
    """
    b, s_len, d = hidden.shape
    chunk = min(cfg.xent_chunk, s_len)
    n_chunks = -(-s_len // chunk)
    pad = n_chunks * chunk - s_len
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))

    def body(carry, xs):
        tot, cnt = carry
        h_c, y_c, m_c = xs
        logits = logits_fn(p, cfg, h_c.transpose(1, 0, 2)).astype(jnp.float32)
        logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, y_c.T[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        nll = (lse - ll) * m_c.T
        return (tot + jnp.sum(nll), cnt + jnp.sum(m_c)), None

    hs = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 2, 0, 3)
    ys = labels.reshape(b, n_chunks, chunk).transpose(1, 2, 0)
    ms = mask.reshape(b, n_chunks, chunk).transpose(1, 2, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys, ms))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg):
    model = get_model(cfg)

    def loss_fn(params, batch):
        if cfg.arch == "encdec":
            hidden, aux = model.forward(params, cfg, batch["dec_tokens"], batch["frames"])
            labels = batch["dec_labels"]
            mask = batch.get("dec_mask", jnp.ones_like(labels, jnp.float32))
        else:
            hidden, aux = model.forward(
                params, cfg, batch["tokens"], batch.get("patch_embeds")
            )
            labels = batch["labels"]
            mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
            if cfg.frontend == "patches":
                # hidden covers [patches | text]; loss only over text positions
                hidden = hidden[:, -labels.shape[1] :]
        loss = xent_chunked(model.logits_fn, params, cfg, hidden, labels, mask)
        return loss + aux, {"xent": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg, opt_cfg: optim_mod.OptConfig):
    """Returns train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics).

    batch leaves have a leading global-batch dim; grad accumulation splits it
    into cfg.microbatch slices.
    """
    loss_fn = make_loss_fn(cfg)
    _, opt_update = optim_mod.make_optimizer(opt_cfg)

    def train_step(params, opt_state, batch):
        micro = max(cfg.microbatch, 1)

        def reshape_micro(x):
            return x.reshape((micro, x.shape[0] // micro) + x.shape[1:])

        mbatch = jax.tree.map(reshape_micro, batch)

        acc_dt = jnp.dtype(cfg.grad_accum_dtype)

        def accum(carry, mb):
            g_acc, l_acc = carry
            mb = jax.tree.map(
                lambda v: constrain(
                    v, ("act_batch",) + ("act_seq",) * (v.ndim - 1)
                ),
                mb,
            )
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            g_acc = jax.tree.map(
                lambda a, g: a + (g.astype(acc_dt) / micro).astype(acc_dt), g_acc, grads
            )
            return (g_acc, l_acc + loss / micro), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (grads, loss), _ = jax.lax.scan(accum, (g0, jnp.float32(0.0)), mbatch)
        new_params, new_opt, opt_metrics = opt_update(params, grads, opt_state)
        metrics = {"loss": loss, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step
