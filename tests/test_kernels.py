"""Per-kernel validation: Pallas (interpret) vs pure-jnp oracles,
swept over shapes and dtypes per the task requirements."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lune_filter import lune_filter
from repro.kernels.pairwise_topk import pairwise_topk


@pytest.mark.parametrize("n,d,k", [(64, 2, 5), (200, 8, 16), (333, 17, 7), (512, 64, 31)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_topk_sweep(n, d, k, dtype):
    rng = np.random.default_rng(n + d + k)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    d2, idx = pairwise_topk(x, k, block_q=128, block_k=128, interpret=True)
    d2_ref, idx_ref = ref.knn_ref(x, k)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref), rtol=tol, atol=tol)
    # indices may differ only at near-ties; check distance-equivalence
    agree = (np.asarray(idx) == np.asarray(idx_ref)).mean()
    assert agree > 0.98


@pytest.mark.parametrize("block_q,block_k", [(32, 64), (128, 256)])
def test_pairwise_topk_blocks(block_q, block_k):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(300, 5)).astype(np.float32))
    d2, idx = pairwise_topk(x, 9, block_q=block_q, block_k=block_k, interpret=True)
    d2_ref, idx_ref = ref.knn_ref(x, 9)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,d,m", [(128, 3, 50), (300, 16, 400), (257, 33, 111)])
def test_lune_filter_sweep(n, d, m):
    rng = np.random.default_rng(n + m)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    d2_ref, _ = ref.knn_ref(x, 8)
    cd2 = d2_ref[:, 5]
    ea = jnp.asarray(rng.integers(0, n, size=m).astype(np.int32))
    eb = jnp.asarray((rng.integers(1, n, size=m) + np.asarray(ea)) % n).astype(jnp.int32)
    d2ab = jnp.sum((x[ea] - x[eb]) ** 2, -1)
    w2 = jnp.maximum(jnp.maximum(cd2[ea], cd2[eb]), d2ab)
    want = np.asarray(ref.lune_filter_ref(x[ea], x[eb], cd2[ea], cd2[eb], ea, eb, w2, x, cd2))
    got = np.asarray(
        lune_filter(
            x[ea], x[eb], cd2[ea], cd2[eb], ea, eb, w2, x, cd2,
            block_e=64, block_c=128, interpret=True,
        )
    )
    assert (got == want).all()


def test_ops_backends_agree():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(200, 6)).astype(np.float32))
    d_j, i_j = ops.knn(x, 10, backend="jnp")
    d_p, i_p = ops.knn(x, 10, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(d_j), np.asarray(d_p), rtol=1e-6, atol=1e-7)
    assert (np.asarray(i_j) == np.asarray(i_p)).all()
