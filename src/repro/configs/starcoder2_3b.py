"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

RoPE, GELU MLP with bias, sliding window 4096.  [arXiv:2402.19173; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    arch="transformer",
    vocab=49152,
    d_model=3072,
    n_layers=30,
    n_heads=24,
    n_kv=2,
    d_head=128,
    d_ff=12288,
    act="gelu",
    mlp_bias=True,
    qkv_bias=True,
    rope_theta=100_000.0,
    window=4096,
    run_long_500k=False,
    skip_note=(
        "sliding-window-only (4096) would bound the cache, but the arch is "
        "full-attention family per the task rule; long_500k skipped"
    ),
)
