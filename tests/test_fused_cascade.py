"""Fused filter cascade: golden edge-set identity against the retained
slot-array path, Pallas-kernel vs jnp-twin parity, tie-overflow fallback,
and the persistent program cache."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import rng as rng_mod
from repro.kernels import fused_cascade, ops


def _moons(n_half=110, seed=3):
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, np.pi, size=(n_half,))
    x = np.concatenate([
        np.stack([np.cos(t), np.sin(t)], 1),
        np.stack([1.0 - np.cos(t), 0.5 - np.sin(t)], 1),
    ]).astype(np.float32)
    return x + rng.normal(0, 0.06, size=x.shape).astype(np.float32)


def _anisotropic(n=220, seed=5):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 2))
    x = x @ np.array([[0.6, -0.6], [-0.35, 0.85]])  # shear
    x[: n // 2] += (4.0, 0.0)
    return x.astype(np.float32)


def _datasets(blobs):
    return {
        "blobs": blobs[0],
        "moons": _moons(),
        "anisotropic": _anisotropic(),
    }


@pytest.mark.parametrize("variant", ["rng_star", "rng"])
def test_fused_matches_slot_path_golden(blobs, variant):
    """Golden: the fused cascade's edge set must be IDENTICAL (values and
    order) to the retained slot-array path on every test dataset — not just
    label-identical."""
    plan = engine.resolve_plan("auto")
    plan_ref = dataclasses.replace(plan, backend="ref")  # forces the slot path
    for name, x in _datasets(blobs).items():
        xj = jnp.asarray(x)
        knn_d2, knn_idx = ops.knn(xj, 9)
        fused = rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant=variant, plan=plan)
        slot = rng_mod.build_rng_graph(
            xj, knn_d2, knn_idx, variant=variant, plan=plan_ref
        )
        assert fused.stats.get("path") == "fused", (name, fused.stats)
        assert "path" not in slot.stats
        np.testing.assert_array_equal(fused.edges, slot.edges, err_msg=name)
        # weights may differ by ulps (same diff-form formula, different
        # compiled programs); the EDGE SET is the bit-exact contract
        np.testing.assert_allclose(fused.d2, slot.d2, rtol=2e-7, err_msg=name)
        np.testing.assert_allclose(
            fused.w2_kmax, slot.w2_kmax, rtol=2e-7, err_msg=name
        )


def test_edge_cascade_pallas_interpret_matches_jnp(blobs):
    """The Pallas kernel (interpret mode) and the jnp twin are the same
    program family: identical verdicts, certificates, and float outputs."""
    x, _ = blobs
    xj = jnp.asarray(x)
    k = 7
    knn_d2, knn_idx = ops.knn(xj, k)
    cd2k = knn_d2[:, -1]
    rng = np.random.default_rng(0)
    m = 513  # deliberately not a tile multiple
    ea = jnp.asarray(rng.integers(0, len(x), m).astype(np.int32))
    eb = jnp.asarray((np.asarray(ea) + 1 + rng.integers(0, len(x) - 1, m)) % len(x)).astype(jnp.int32)
    valid = jnp.asarray(rng.random(m) > 0.1)
    for k_check in (2, k):
        out_j = fused_cascade.edge_cascade(
            xj, cd2k, knn_idx, knn_d2, ea, eb, valid,
            k_check=k_check, backend="jnp",
        )
        out_p = fused_cascade.edge_cascade(
            xj, cd2k, knn_idx, knn_d2, ea, eb, valid,
            k_check=k_check, backend="pallas_interpret",
        )
        vj = np.asarray(valid)
        np.testing.assert_array_equal(np.asarray(out_j[0]), np.asarray(out_p[0]))
        np.testing.assert_array_equal(np.asarray(out_j[1]), np.asarray(out_p[1]))
        np.testing.assert_allclose(
            np.asarray(out_j[2])[vj], np.asarray(out_p[2])[vj], rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(
            np.asarray(out_j[3])[vj], np.asarray(out_p[3])[vj], rtol=1e-6, atol=1e-7
        )


def test_staged_equals_unstaged_verdict(blobs):
    """Stage-1 removals are a strict subset of the full check's: staging can
    never change the final verdict (the exactness argument behind the fused
    pipeline)."""
    x, _ = blobs
    xj = jnp.asarray(x)
    knn_d2, knn_idx = ops.knn(xj, 9)
    cd2k = knn_d2[:, -1]
    rng = np.random.default_rng(1)
    m = 400
    ea = jnp.asarray(rng.integers(0, len(x), m).astype(np.int32))
    eb = jnp.asarray((np.asarray(ea) + 1 + rng.integers(0, len(x) - 1, m)) % len(x)).astype(jnp.int32)
    valid = jnp.ones((m,), bool)
    killed1 = fused_cascade.edge_cascade(
        xj, cd2k, knn_idx, knn_d2, ea, eb, valid, k_check=2, backend="jnp"
    )[0]
    killed_full = fused_cascade.edge_cascade(
        xj, cd2k, knn_idx, knn_d2, ea, eb, valid, k_check=9, backend="jnp"
    )[0]
    k1, kf = np.asarray(killed1), np.asarray(killed_full)
    assert (~kf[k1]).sum() == 0  # stage-1 kills are a subset of full kills
    assert kf.sum() > k1.sum() > 0  # and staging actually prunes something


def test_tie_overflow_falls_back_to_slot_path():
    """Mass-duplicated points overflow the bounded per-row emission; the
    build must detect that EXACTLY and fall back to the dense slot path,
    producing the identical graph the ref backend computes."""
    rng = np.random.default_rng(7)
    base = rng.normal(size=(40, 2)).astype(np.float32)
    x = np.repeat(base, 8, axis=0)  # every point duplicated 8x
    xj = jnp.asarray(x)
    knn_d2, knn_idx = ops.knn(xj, 7)
    plan = engine.resolve_plan("auto")
    g = rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant="rng_star", plan=plan)
    assert g.stats.get("path") != "fused"  # overflow forced the fallback
    g_ref = rng_mod.build_rng_graph(
        xj, knn_d2, knn_idx, variant="rng_star",
        plan=dataclasses.replace(plan, backend="ref"),
    )
    np.testing.assert_array_equal(g.edges, g_ref.edges)


def test_program_cache_persists_across_plans(blobs):
    """Two Plan instances over the same data shape share cached programs."""
    x, _ = blobs
    xj = jnp.asarray(x)
    knn_d2, knn_idx = ops.knn(xj, 7)
    p1 = engine.resolve_plan("auto")
    p2 = engine.resolve_plan("auto")
    assert p1 is not p2
    rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant="rng_star", plan=p1)
    before = set(engine.plan.program_cache_info())
    assert any(k[0] in ("tier_emit", "rowpath_emit") for k in before)
    rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant="rng_star", plan=p2)
    assert set(engine.plan.program_cache_info()) == before  # no new builds


def test_fused_pack_limit_falls_back():
    """n beyond the int32 packing limit must route to the slot path."""
    assert rng_mod._PACK_LIMIT ** 2 + rng_mod._PACK_LIMIT < 2 ** 31
    rng = np.random.default_rng(2)
    x = rng.normal(size=(60, 2)).astype(np.float32)
    xj = jnp.asarray(x)
    knn_d2, knn_idx = ops.knn(xj, 5)
    plan = engine.resolve_plan("auto")
    import unittest.mock as mock

    with mock.patch.object(rng_mod, "_PACK_LIMIT", 10):
        g = rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant="rng_star", plan=plan)
    assert g.stats.get("path") != "fused"
    g2 = rng_mod.build_rng_graph(xj, knn_d2, knn_idx, variant="rng_star", plan=plan)
    assert g2.stats.get("path") == "fused"
    np.testing.assert_array_equal(g.edges, g2.edges)
