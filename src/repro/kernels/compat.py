"""Pallas API-drift shims shared by the kernel modules."""

from jax.experimental.pallas import tpu as pltpu

# CompilerParams was TPUCompilerParams before jax 0.5
COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
