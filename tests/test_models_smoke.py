"""Per-arch smoke tests (reduced configs): forward shapes + no NaNs, one
train step, decode-vs-forward consistency, SSD-vs-recurrence oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model, init_params
from repro.train import optim as optim_mod
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


def _train_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.arch == "encdec":
        dec = max(4, int(s * cfg.dec_seq_frac))
        return {
            "frames": jnp.asarray(rng.normal(size=(b, s, cfg.frontend_dim)).astype(np.float32)),
            "dec_tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, dec)).astype(np.int32)),
            "dec_labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, dec)).astype(np.int32)),
            "dec_mask": jnp.ones((b, dec), jnp.float32),
        }
    if cfg.frontend == "patches":
        nt = s - cfg.frontend_tokens_4k
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, nt)).astype(np.int32)),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(b, cfg.frontend_tokens_4k, cfg.frontend_dim)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, nt)).astype(np.int32)),
            "mask": jnp.ones((b, nt), jnp.float32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)),
        "mask": jnp.ones((b, s), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params, _ = init_params(cfg, KEY)
    batch = _train_batch(cfg)
    opt_cfg = optim_mod.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                                  state_dtype=cfg.optimizer_state_dtype)
    opt_init, _ = optim_mod.make_optimizer(opt_cfg)
    opt_state = opt_init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    params2, opt_state2, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["loss"]) > 0
    # params actually moved
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0, arch


@pytest.mark.parametrize(
    "arch",
    ["qwen2_1_5b", "gemma3_4b", "starcoder2_3b", "deepseek_v2_lite_16b",
     "kimi_k2_1t_a32b", "mamba2_780m", "recurrentgemma_2b", "llava_next_34b"],
)
@pytest.mark.slow
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:  # capacity dropping differs by token count: disable
        cfg = dataclasses.replace(cfg, capacity_factor=999.0)
    m = get_model(cfg)
    p, _ = m.init(cfg, KEY)
    B, S, T = 2, 24, 6
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S + T), 0, cfg.vocab)
    h, _ = m.forward(p, cfg, toks)
    ref = m.logits_fn(p, cfg, h)
    last, cache = m.prefill(p, cfg, toks[:, :S], max_len=S + T, cache_dtype=jnp.float32)
    outs = [last]
    for t in range(T - 1):
        lg, cache = m.decode_step(p, cfg, cache, toks[:, S + t:S + t + 1])
        outs.append(lg)
    serve = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(serve - ref[:, S - 1:S + T - 1])))
    assert err < 1e-3, f"{arch}: {err}"


def test_ssd_chunked_matches_recurrence():
    """Mamba2 SSD chunked algorithm vs naive per-token recurrence."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    B, S, H, P, N, Q = 2, 64, 3, 8, 16, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    b_in = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    c_in = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)).astype(np.float32))
    a_log = jnp.asarray(np.log(rng.uniform(0.5, 4.0, size=(H,))).astype(np.float32))

    y = np.asarray(ssd_chunked(x, b_in, c_in, dt, a_log, Q))

    # naive recurrence
    a = -np.exp(np.asarray(a_log))
    s = np.zeros((B, H, N, P))
    y_ref = np.zeros((B, S, H, P))
    xn, bn, cn, dtn = map(np.asarray, (x, b_in, c_in, dt))
    for t in range(S):
        dec = np.exp(dtn[:, t, :, None, None] * -np.exp(np.asarray(a_log))[None, :, None, None])
        s = s * dec + np.einsum("bn,bhp->bhnp", bn[:, t], xn[:, t] * dtn[:, t][..., None])
        y_ref[:, t] = np.einsum("bn,bhnp->bhp", cn[:, t], s)
    np.testing.assert_allclose(y, y_ref, rtol=2e-3, atol=2e-4)


def test_rglru_scan_matches_recurrence():
    from repro.models.griffin import _rglru
    from repro.configs import get_config

    cfg = get_config("recurrentgemma_2b").reduced()
    m = get_model(cfg)
    p, _ = m.init(cfg, KEY)
    pl = jax.tree.map(lambda v: v[0], p["period"]["mix0"])
    rng = np.random.default_rng(0)
    B, S = 2, 12
    h = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)) * 0.1
    full, (conv_f, lru_f) = _rglru(pl, h)
    # step-by-step
    state = (jnp.zeros((B, cfg.d_conv - 1, cfg.d_model), jnp.float32),
             jnp.zeros((B, cfg.d_model), jnp.float32))
    outs = []
    for t in range(S):
        o, state = _rglru(pl, h[:, t:t+1], state, single_step=True)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-3, atol=1e-4)
