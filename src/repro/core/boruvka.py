"""Minimum spanning trees in JAX: batched edge-list Boruvka + dense Prim.

``boruvka_mst``  — MST over an explicit edge list (the RNG).  Fully
vectorized label-propagation Boruvka: per-round two-phase scatter-min per
component (first the f32 weight, then — among weight-ties — the edge id),
symmetric-pair breaking, pointer-jumping union.  <= ceil(log2 n) rounds
inside ``lax.while_loop``.  The two-phase min is exactly a lexicographic
(w, edge-id) key, which makes the chosen MST unique => deterministic and
cycle-free even with duplicated mrd weights (which are COMMON: every edge
whose weight is a shared core distance ties).  (A single packed uint64 key
would need x64 mode; the two-phase form is also cheaper on TPU.)

``boruvka_mst_range`` — the paper's headline trick, TPU-shaped: ONE program
computes the MST for EVERY mpts value by vmapping over the (kmax, m) weight
matrix from ``mrd.reweight_all_mpts``.

``prim_dense_mst`` — the baseline HDBSCAN* MST over the *complete* mutual
reachability graph (never materialized; one mrd row per iteration), used by
the paper's comparison baseline and by tests as a same-framework oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnames=("n",))
def boruvka_mst(ea: jax.Array, eb: jax.Array, w: jax.Array, *, n: int):
    """MST of an undirected weighted graph given as an explicit edge list.

    Args:
      ea, eb: (m,) int32 endpoints.
      w: (m,) non-negative float32 weights.
      n: number of vertices (static).
    Returns:
      in_mst: (m,) bool mask of MST edges (n-1 True entries if connected).
    """
    m = w.shape[0]
    wf = w.astype(jnp.float32)
    idx = jnp.arange(m, dtype=jnp.int32)
    iota_n = jnp.arange(n)

    def cond(state):
        comp, in_mst, n_comp, progressed, rounds = state
        return (n_comp > 1) & progressed & (rounds < 64)

    def body(state):
        comp, in_mst, n_comp, _, rounds = state
        ca, cb = comp[ea], comp[eb]
        cross = ca != cb
        wc = jnp.where(cross, wf, jnp.inf)
        # phase 1: minimum cross-edge weight per component
        wmin = jnp.full((n,), jnp.inf, jnp.float32)
        wmin = wmin.at[ca].min(wc).at[cb].min(wc)
        # phase 2: among weight-ties, minimum edge id per component
        ia = jnp.where(cross & (wc == wmin[ca]), idx, m)
        ib = jnp.where(cross & (wc == wmin[cb]), idx, m)
        best_idx = jnp.full((n,), m, jnp.int32).at[ca].min(ia).at[cb].min(ib)
        has = best_idx < m
        eidx = jnp.where(has, best_idx, 0)
        # component each root connects to via its chosen edge
        pa = comp[ea[eidx]]
        pb = comp[eb[eidx]]
        other = jnp.where(pa == iota_n, pb, pa)
        parent = jnp.where(has, other, iota_n)
        # break mutual pairs: keep the smaller id as root
        parent = jnp.where((parent[parent] == iota_n) & (iota_n < parent), iota_n, parent)
        # pointer jumping to roots
        def pj_body(p):
            return p[p]

        def pj_cond(p):
            return jnp.any(p[p] != p)

        parent = jax.lax.while_loop(pj_cond, pj_body, parent)
        # mark chosen edges (scatter with drop for components w/o a choice)
        mark_idx = jnp.where(has, eidx, m)
        in_mst = in_mst.at[mark_idx].set(True, mode="drop")
        new_comp = parent[comp]
        new_n = jnp.sum(new_comp == iota_n).astype(jnp.int32)
        progressed = jnp.any(has)
        return new_comp, in_mst, new_n, progressed, rounds + 1

    init = (
        iota_n,
        jnp.zeros((m,), bool),
        jnp.int32(n),
        jnp.bool_(True),
        jnp.int32(0),
    )
    _, in_mst, n_comp, _, _ = jax.lax.while_loop(cond, body, init)
    return in_mst


def _boruvka_mst_range(ea: jax.Array, eb: jax.Array, w_range: jax.Array, *, n: int):
    """MSTs for every mpts at once: w_range (R, m) -> in_mst (R, m) bool.

    Unjitted body of ``boruvka_mst_range``.
    ``dist.cluster_parallel.sharded_mst_range`` calls THIS inside its
    shard_map region: nesting the jitted wrapper under shard_map miscompiles
    the flat-scatter while_loop on multi-device CPU (wrong MSTs on every
    shard but the first); the plain function traces inline and is correct.

    Natively batched (not a vmap of ``boruvka_mst``): each row's edges are
    pre-ranked ONCE by their lexicographic (w, edge id) order — the IEEE
    bit pattern of a non-negative f32 is order-preserving as an int32, so
    the ranking is one two-int-key sort, cheaper than a stable f32 argsort
    — and the per-round scatter-min then runs on int32 dense ranks: a
    single one-phase min instead of the f32-weight + tie-id two-phase,
    with all R rows sharing one flat (R*n) scatter.  Rank order IS the
    (w, edge id) key the two-phase min implements, so the chosen MSTs are
    bit-identical to ``boruvka_mst`` (asserted by tests/test_mst.py).
    """
    R, m = w_range.shape
    wf = w_range.astype(jnp.float32)
    wf = jnp.where(wf == 0.0, jnp.float32(0.0), wf)  # -0.0 bitcast would misorder
    w_bits = jax.lax.bitcast_convert_type(wf, jnp.int32)
    iota_m = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (R, m))
    _, order = jax.lax.sort((w_bits, iota_m), dimension=1, num_keys=2)
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    rank = jnp.zeros((R, m), jnp.int32).at[rows, order].set(iota_m)
    big = jnp.int32(m)
    iota_n = jnp.arange(n, dtype=jnp.int32)[None, :]
    flat_off = (jnp.arange(R, dtype=jnp.int32) * n)[:, None]

    def cond(state):
        _, _, n_comp, progressed, rounds = state
        return jnp.any(n_comp > 1) & progressed & (rounds < 64)

    def body(state):
        comp, in_mst, _, _, rounds = state
        ca = jnp.take(comp, ea, axis=1)                             # (R, m)
        cb = jnp.take(comp, eb, axis=1)
        cross = ca != cb
        rk = jnp.where(cross, rank, big)
        # one-phase scatter-min of ranks per (row, component), flat over R*n
        best = (
            jnp.full((R * n,), big, jnp.int32)
            .at[(flat_off + ca).ravel()]
            .min(rk.ravel())
            .at[(flat_off + cb).ravel()]
            .min(rk.ravel())
            .reshape(R, n)
        )
        has = best < big
        eidx = jnp.take_along_axis(order, jnp.where(has, best, 0), axis=1)
        pa = jnp.take_along_axis(comp, ea[eidx], axis=1)
        pb = jnp.take_along_axis(comp, eb[eidx], axis=1)
        other = jnp.where(pa == iota_n, pb, pa)
        parent = jnp.where(has, other, iota_n)
        # break mutual pairs: keep the smaller id as root
        pp = jnp.take_along_axis(parent, parent, axis=1)
        parent = jnp.where((pp == iota_n) & (iota_n < parent), iota_n, parent)

        def pj_body(p):
            return jnp.take_along_axis(p, p, axis=1)

        def pj_cond(p):
            return jnp.any(jnp.take_along_axis(p, p, axis=1) != p)

        parent = jax.lax.while_loop(pj_cond, pj_body, parent)
        mark_idx = jnp.where(has, eidx, m)
        in_mst = in_mst.at[rows, mark_idx].set(True, mode="drop")
        new_comp = jnp.take_along_axis(parent, comp, axis=1)
        n_comp = jnp.sum(new_comp == iota_n, axis=1).astype(jnp.int32)
        return new_comp, in_mst, n_comp, jnp.any(has), rounds + 1

    init = (
        jnp.broadcast_to(iota_n, (R, n)),
        jnp.zeros((R, m), bool),
        jnp.full((R,), n, jnp.int32),
        jnp.bool_(True),
        jnp.int32(0),
    )
    _, in_mst, _, _, _ = jax.lax.while_loop(cond, body, init)
    return in_mst


boruvka_mst_range = functools.partial(jax.jit, static_argnames=("n",))(
    _boruvka_mst_range
)


@jax.jit
def prim_dense_mst(x: jax.Array, cd2_col: jax.Array):
    """Prim's MST over the implicit complete mrd graph for ONE mpts.

    This is the paper's (optimized) baseline unit of work: O(n^2) mrd
    evaluations, one row per iteration, nothing materialized.

    Returns (parent_src (n,), w2 (n,)): for each vertex != start, the MST edge
    (parent_src[v], v) with squared mrd weight w2[v]; w2[start] = 0.
    """
    n, _ = x.shape
    xf = x.astype(jnp.float32)

    def mrd_row(u):
        diff = xf - xf[u]
        d2 = jnp.sum(diff * diff, axis=-1)  # diff form: no cancellation noise
        return jnp.maximum(jnp.maximum(cd2_col[u], cd2_col), d2)

    def body(i, state):
        in_tree, best_w2, best_src, last = state
        row = mrd_row(last)
        better = (row < best_w2) & ~in_tree
        best_w2 = jnp.where(better, row, best_w2)
        best_src = jnp.where(better, last, best_src)
        pick = jnp.argmin(jnp.where(in_tree, jnp.inf, best_w2))
        in_tree = in_tree.at[pick].set(True)
        return in_tree, best_w2, best_src, pick

    in_tree = jnp.zeros((n,), bool).at[0].set(True)
    best_w2 = jnp.full((n,), jnp.inf, jnp.float32).at[0].set(0.0)
    best_src = jnp.zeros((n,), jnp.int32)
    state = (in_tree, best_w2, best_src, jnp.int32(0))
    in_tree, best_w2, best_src, _ = jax.lax.fori_loop(0, n - 1, body, state)
    return best_src, jnp.where(jnp.arange(n) == 0, 0.0, best_w2)
