"""Training-loop integration: loss descends, checkpoint resume is bit-exact
after a simulated preemption, optimizer variants behave."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import optim as optim_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
ENV.pop("XLA_FLAGS", None)


@pytest.mark.slow
def test_loss_descends(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "qwen2_1_5b", "--reduced", "--steps", "12",
        "--global-batch", "4", "--seq-len", "64", "--lr", "3e-3",
    ])
    assert losses[-1] < losses[0] - 0.1, losses


@pytest.mark.slow
def test_preempt_resume_bit_exact(tmp_path):
    """Run A: 10 steps straight.  Run B: preempted at 5 (hard exit), then
    resumed.  Final checkpoints must match bit-for-bit."""
    a_dir = str(tmp_path / "a")
    b_dir = str(tmp_path / "b")
    common = [
        sys.executable, "-m", "repro.launch.train", "--arch", "qwen2_1_5b",
        "--reduced", "--steps", "10", "--global-batch", "4",
        "--seq-len", "32", "--ckpt-every", "5",
    ]
    subprocess.run(common + ["--ckpt-dir", a_dir], env=ENV, check=True,
                   capture_output=True)
    r = subprocess.run(common + ["--ckpt-dir", b_dir, "--preempt-after", "5"],
                       env=ENV, capture_output=True)
    assert r.returncode == 42, r.stderr.decode()[-500:]
    r = subprocess.run(common + ["--ckpt-dir", b_dir], env=ENV, check=True,
                       capture_output=True)

    sa, step_a = ckpt_lib.restore(a_dir)
    sb, step_b = ckpt_lib.restore(b_dir)
    assert step_a == step_b == 10
    la, lb = jax.tree.leaves(sa["params"]), jax.tree.leaves(sb["params"])
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"step": jnp.int32(7)},
    }
    ckpt_lib.save(str(tmp_path), 7, state)
    out, step = ckpt_lib.restore(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(out["params"]["w"], np.arange(12.0).reshape(3, 4))
    assert ckpt_lib.latest_step(str(tmp_path)) == 7


def test_data_determinism():
    cfg = data_lib.DataConfig(seed=3, vocab=1000, seq_len=64, global_batch=4)
    b1 = data_lib.train_batch(cfg, 5)
    b2 = data_lib.train_batch(cfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = data_lib.train_batch(cfg, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_quadratic(state_dtype):
    """AdamW minimizes a quadratic regardless of state dtype."""
    cfg = optim_mod.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                              weight_decay=0.0, state_dtype=state_dtype)
    init, update = optim_mod.make_optimizer(cfg)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)).astype(np.float32))
    params = {"w": jnp.zeros((4, 64))}
    state = init(params)

    @jax.jit
    def step(params, state):
        g = {"w": 2 * (params["w"] - target)}
        return update(params, g, state)

    for _ in range(150):
        params, state, m = step(params, state)
    err = float(jnp.mean(jnp.abs(params["w"] - target)))
    # int8 moment quantization adds noise; the point is convergence
    assert err < (0.3 if state_dtype == "int8" else 0.05), err


def test_adafactor_runs():
    cfg = optim_mod.OptConfig(name="adafactor", lr=0.05, warmup_steps=1,
                              total_steps=100, weight_decay=0.0)
    init, update = optim_mod.make_optimizer(cfg)
    target = jnp.ones((8, 16))
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    state = init(params)
    for _ in range(100):
        g = {"w": 2 * (params["w"] - target), "b": params["b"]}
        params, state, m = update(params, g, state)
    assert float(jnp.mean(jnp.abs(params["w"] - target))) < 0.2


def test_straggler_detector():
    from repro.train.metrics import StepTimer

    t = StepTimer(alpha=0.5, slow_factor=2.0)
    for _ in range(4):
        t.observe(0.01)
    t.observe(0.08)
    assert t.is_straggler
    assert t.stragglers == 1
