"""gemma3-4b [dense] — 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (window 1024; every 6th layer global, theta 1M on
global / 10k on local), head_dim 256, GeGLU, logit softcap, 128k context
design target.  [hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    arch="transformer",
    vocab=262144,
    d_model=2560,
    n_layers=34,
    n_heads=8,
    n_kv=4,
    d_head=256,
    d_ff=10240,
    act="geglu",
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    window=1024,
    window_period=6,                # layers 6, 12, ... are global
    logit_softcap=30.0,
    microbatch=2,
    # 5:1 local:global => only ~1/6 of layers carry the 500k KV; the arch's
    # design point is long context, so the long_500k cell runs.
    run_long_500k=True,
)
