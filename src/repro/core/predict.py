"""Batched out-of-sample prediction over the fitted multi-MST state.

The paper's pitch is "one fit buys a hundred hierarchies"; this module makes
the fitted state answer queries about points it has never seen, for *every*
fitted mpts row at once (McInnes & Healy's ``approximate_predict``, batched
across the density range).  Dataflow (docs/architecture.md "Prediction &
serving"):

  fitted state (X, cd2, condensed trees)   +   query batch Q (q, d)
    │  plan.query_knn(Q, X, kmax-1)      ONE cross-set device pass — the
    ▼                                    (kmax-1)-NN list yields every query
  qd2, qidx (q, kmax-1)                  core distance c_m(Q), m in [1, kmax]
    │  attach program (cached by         per mpts row r: query core distance,
    │  (q bucket, kq, kmax, R))          mutual reachability to each fitted
    ▼                                    neighbour, argmin attach   ⇣predict
  lambdas, neighbors (R, q)
    │  per-mpts condensed-tree walk     host, vectorized over queries: climb
    ▼                                   from the attachment point's departure
  labels, probabilities (R, q)          cluster to the first cluster alive at
                                        lambda_q, then to its selected
                                        ancestor (hdbscan-style membership)

The prediction is *approximate* in exactly the standard sense: the query is
ranked against the fitted tree without refitting, so core distances of
fitted points are not perturbed by the query's presence.  Off cluster
boundaries this matches the refit-including-the-point oracle
(tests/test_predict.py pins it on blobs/moons/aniso holdouts).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from .. import engine
from .multi import HierarchyResult, MultiMSTResult


@dataclasses.dataclass
class PredictResult:
    """Per-mpts out-of-sample assignments for one query batch.

    Row ``i`` of each array corresponds to ``mpts_values[i]``; columns are
    query points.  ``labels`` match the fitted labelling of that mpts level
    (-1 = noise), ``probabilities`` are hdbscan-style cluster membership
    strengths in [0, 1], ``lambdas`` the density level at which each query
    attaches, and ``neighbors`` the fitted point it attaches through.
    """

    mpts_values: list[int]
    labels: np.ndarray         # (R, q) int64
    probabilities: np.ndarray  # (R, q) float64
    lambdas: np.ndarray        # (R, q) float64
    neighbors: np.ndarray      # (R, q) int64

    def row(self, mpts: int) -> tuple[np.ndarray, np.ndarray]:
        """(labels, probabilities) at one density level."""
        r = self.mpts_values.index(mpts)
        return self.labels[r], self.probabilities[r]


# ---------------------------------------------------------------------------
# Device stage: query kNN -> per-row attachment
# ---------------------------------------------------------------------------


def _build_attach(q_pad: int, kq: int, kmax: int, R: int):
    """Attach program for one (query bucket, kq, kmax, R) shape family.

    Operands are a pure function of the key: qd2/qidx (q_pad, kq), the
    pre-gathered neighbour core distances (q_pad, kq, kmax), and the mpts
    column index (R,).  No operand carries the dataset size n, so one
    program serves every fitted dataset at this bucket.
    """
    import jax

    @jax.jit
    def run(qd2, qidx, ncd2, mcol):
        # query core distances: col m-1 = c_m(q)^2 (c_1 = 0, paper convention)
        qcd2 = jnp.concatenate([jnp.zeros((q_pad, 1), qd2.dtype), qd2], axis=1)
        qc = qcd2[:, mcol]                      # (q, R)
        nc = ncd2[:, :, mcol]                   # (q, kq, R)
        mrd2 = jnp.maximum(
            jnp.maximum(qd2[:, :, None], qc[:, None, :]), nc
        )                                       # (q, kq, R)
        # argmin is first-occurrence and qd2 ascends, so mrd ties resolve to
        # the *nearest* fitted neighbour — deterministic across backends
        # (the shared refine pass makes qd2/qidx identical everywhere).
        j = jnp.argmin(mrd2, axis=1)            # (q, R)
        best = jnp.take_along_axis(mrd2, j[:, None, :], axis=1)[:, 0, :]
        nbr = jnp.take_along_axis(qidx, j, axis=1)  # (q, R)
        lam = jnp.where(best > 0.0, 1.0 / jnp.sqrt(best), jnp.inf)
        return lam.T, nbr.T                     # (R, q)

    return run


def attach_queries(
    xq,
    x,
    cd2,
    mpts_values: Sequence[int],
    *,
    plan: "engine.Plan",
) -> tuple[np.ndarray, np.ndarray]:
    """Query kNN + mutual-reachability attachment for every mpts row at once.

    Args:
      xq:  (q, d) query batch.
      x:   (n, d) fitted points.
      cd2: (n, kmax) squared core distances of the fitted points.
    Returns:
      (lambdas, neighbors), each (R, q): the density level at which each
      query joins the tree of mpts row r, and the fitted point it attaches
      through (its mutual-reachability argmin neighbour).
    """
    xq = jnp.asarray(xq)
    x = jnp.asarray(x)
    cd2 = jnp.asarray(cd2)
    q = xq.shape[0]
    kmax = cd2.shape[1]
    kq = kmax - 1
    R = len(mpts_values)

    qd2, qidx = plan.query_knn(xq, x, kq)

    # bucket the query axis so the attach program is keyed by scale, not by
    # the exact batch size; padded queries carry +inf distances (lambda 0,
    # sliced off before the host ever sees them)
    q_pad = max(64, 1 << max(0, int(q - 1).bit_length()))
    if q_pad != q:
        qd2 = jnp.concatenate(
            [qd2, jnp.full((q_pad - q, kq), jnp.inf, qd2.dtype)]
        )
        qidx = jnp.concatenate([qidx, jnp.zeros((q_pad - q, kq), qidx.dtype)])
    # gather the neighbour core-distance rows OUTSIDE the cached program so
    # its operand shapes never mention the dataset size n
    ncd2 = cd2[qidx]
    mcol = jnp.asarray(np.asarray(mpts_values, np.int32) - 1)

    fn = engine.cached_program(
        ("predict_attach", q_pad, kq, kmax, R), lambda: _build_attach(q_pad, kq, kmax, R)
    )
    lam, nbr = engine.to_host(fn(qd2, qidx, ncd2, mcol), "predict")
    return lam[:, :q], nbr[:, :q]


# ---------------------------------------------------------------------------
# Host stage: condensed-tree walk
# ---------------------------------------------------------------------------


def _label_max_lambda(
    labels: np.ndarray, point_lambda: np.ndarray, n_labels: int
) -> np.ndarray:
    """Deepest finite departure lambda per selected label (0 if none)."""
    max_lam = np.zeros(max(n_labels, 1))
    finite = (labels >= 0) & np.isfinite(point_lambda)
    np.maximum.at(max_lam, labels[finite], point_lambda[finite])
    return max_lam


def _strength(lam: np.ndarray, max_lam: np.ndarray) -> np.ndarray:
    """hdbscan-style membership strength: lambda relative to the cluster's
    deepest departure, clipped to [0, 1].  ``max_lam`` is finite by
    construction (zeros + finite maxima); a cluster with no finite contrast
    (all departures at lambda 0 or inf) gives full membership."""
    with np.errstate(invalid="ignore", divide="ignore"):
        return np.where(max_lam > 0.0, np.clip(lam / max_lam, 0.0, 1.0), 1.0)


@dataclasses.dataclass
class WalkTable:
    """Per-mpts walk state, derived once from a HierarchyResult.

    Compact cluster indices (0..C-1, root first — the condensed labelling
    assigns every parent a smaller id than its children, so ascending id is
    a topological order).
    """

    pt_cluster: np.ndarray  # (n,) compact idx of the cluster each point departs
    parent: np.ndarray      # (C,) compact parent idx (root points to itself)
    birth: np.ndarray       # (C,) lambda at which the cluster was born
    sel_label: np.ndarray   # (C,) label of the nearest selected ancestor, or -1
    max_lam: np.ndarray     # (L,) finite-capped max departure lambda per label
    root: int               # compact idx of the root (== 0)


def build_walk_table(h: HierarchyResult) -> WalkTable:
    """Flatten one condensed tree into the arrays the query walk needs."""
    tree = h.condensed
    n = tree.n_points
    cluster_rows = tree.child >= n
    cids = np.concatenate([[tree.root], tree.child[cluster_rows]]).astype(np.int64)
    order = np.argsort(cids)
    scids = cids[order]
    C = len(scids)

    def to_idx(ids):
        return np.searchsorted(scids, ids)

    parent = np.arange(C, dtype=np.int64)
    birth = np.zeros(C)
    ci = to_idx(tree.child[cluster_rows])
    parent[ci] = to_idx(tree.parent[cluster_rows])
    birth[ci] = tree.lam[cluster_rows]
    root = int(to_idx(np.int64(tree.root)))

    # nearest selected ancestor: ascending compact idx is top-down, so one
    # pass suffices (the root's parent is itself, resolved first)
    sel_rank = {c: i for i, c in enumerate(sorted(h.selected))}
    sel_label = np.full(C, -1, np.int64)
    for i in range(C):
        own = sel_rank.get(int(scids[i]), -1)
        sel_label[i] = own if own >= 0 else (sel_label[parent[i]] if i != root else -1)

    point_rows = ~cluster_rows
    pt_cluster = np.zeros(n, np.int64)
    pt_cluster[tree.child[point_rows]] = to_idx(tree.parent[point_rows])

    max_lam = _label_max_lambda(h.labels, np.asarray(h.point_lambda), len(sel_rank))
    return WalkTable(
        pt_cluster=pt_cluster,
        parent=parent,
        birth=birth,
        sel_label=sel_label,
        max_lam=max_lam,
        root=root,
    )


def walk_queries(
    table: WalkTable, neighbors: np.ndarray, lambdas: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Condensed-tree walk for one mpts row, vectorized over the query batch.

    Each query starts at the cluster its attachment point departs from and
    climbs while that cluster was born at a *higher* density than the query
    reaches (birth lambda > lambda_q) — the query only exists in clusters
    already alive at its own density.  The landing cluster's nearest
    selected ancestor is the label; membership probability compares
    lambda_q against the cluster's deepest departure (hdbscan-style).
    """
    c = table.pt_cluster[neighbors]
    while True:
        move = (table.birth[c] > lambdas) & (c != table.root)
        if not move.any():
            break
        c = np.where(move, table.parent[c], c)
    labels = table.sel_label[c]

    probs = np.zeros(len(labels))
    member = labels >= 0
    probs[member] = _strength(lambdas[member], table.max_lam[labels[member]])
    return labels, probs


def membership_probabilities(h: HierarchyResult) -> np.ndarray:
    """Per-fitted-point cluster membership strength in [0, 1] (0 = noise).

    hdbscan-style: a point's strength is its departure lambda relative to
    the deepest (finite) departure in its cluster — 1.0 at the cluster core,
    tapering toward the cluster's edge.
    """
    lam_pt = np.asarray(h.point_lambda)
    probs = np.zeros(len(h.labels))
    member = h.labels >= 0
    if not member.any():
        return probs
    max_lam = _label_max_lambda(h.labels, lam_pt, int(h.labels.max()) + 1)
    probs[member] = _strength(lam_pt[member], max_lam[h.labels[member]])
    return probs


# ---------------------------------------------------------------------------
# Range driver
# ---------------------------------------------------------------------------


def validate_queries(xq: np.ndarray, n_features: int | None = None) -> None:
    """Reject malformed query batches with a usable message.

    Mirrors ``MultiHDBSCAN.fit``'s input validation: a NaN coordinate never
    compares, so it would silently pick arbitrary neighbours and return a
    plausible-looking but meaningless label — fail loudly instead.
    """
    if xq.ndim != 2:
        raise ValueError(f"Q must be 2-d (n_queries, n_features); got {xq.shape}")
    if n_features is not None and xq.shape[1] != n_features:
        raise ValueError(f"Q must be 2-d with {n_features} features; got {xq.shape}")
    if xq.size and not np.isfinite(xq).all():
        bad = ~np.isfinite(xq)
        rows = np.flatnonzero(bad.any(axis=1))
        raise ValueError(
            f"Q contains {int(bad.sum())} non-finite value(s) (NaN or inf) "
            f"in {len(rows)} row(s), first at row {int(rows[0])}"
        )


def predict_range(
    msts: MultiMSTResult,
    x,
    xq,
    hierarchy_for: Callable[[int], HierarchyResult],
    *,
    plan: "engine.Plan",
    mpts_values: Sequence[int] | None = None,
    table_cache: dict[int, WalkTable] | None = None,
) -> PredictResult:
    """Out-of-sample assignment of a query batch for every requested mpts.

    ``hierarchy_for`` supplies (typically cached) per-mpts extractions;
    ``table_cache`` (optional, mutated) reuses flattened walk tables across
    calls.  Since the fitted state is selection-agnostic but the walk
    tables are not, ``api.FittedModel`` passes one cache per
    ``SelectionPolicy`` here (bounded alongside its hierarchy LRU), and
    binds ``hierarchy_for`` to the same policy.
    """
    xq = np.asarray(xq)
    validate_queries(xq)
    mpts_list = list(mpts_values) if mpts_values is not None else list(msts.mpts_values)
    for m in mpts_list:
        msts.row_of(m)  # raises KeyError on values outside the fitted range
    R = len(mpts_list)
    if xq.shape[0] == 0:  # empty batch: empty result, no device program
        return PredictResult(
            mpts_values=mpts_list,
            labels=np.full((R, 0), -1, np.int64),
            probabilities=np.zeros((R, 0)),
            lambdas=np.zeros((R, 0)),
            neighbors=np.zeros((R, 0), np.int64),
        )

    lam, nbr = attach_queries(xq, x, msts.cd2, mpts_list, plan=plan)

    q = xq.shape[0]
    labels = np.full((R, q), -1, np.int64)
    probs = np.zeros((R, q))
    for r, mpts in enumerate(mpts_list):
        if table_cache is not None and mpts in table_cache:
            table = table_cache[mpts]
        else:
            table = build_walk_table(hierarchy_for(mpts))
            if table_cache is not None:
                table_cache[mpts] = table
        labels[r], probs[r] = walk_queries(table, nbr[r], lam[r])
    return PredictResult(
        mpts_values=mpts_list,
        labels=labels,
        probabilities=probs,
        lambdas=lam.astype(np.float64),
        neighbors=nbr.astype(np.int64),
    )
