"""Fused filter-cascade program family: d2 + mrd weight + kNN-lune verdict +
core-distance certificate in ONE program per edge chunk.

Paper §IV-E, Algorithm 1 lines 13-21, restructured for accelerators.  The
PR-2 pipeline round-tripped every SBCN candidate through a padded slot array
-> scatter compaction -> a separate chunked ``_knn_lune_check`` map -> a
separate certificate pass.  Here the whole per-edge cascade is one fused
program (Pallas kernel on TPU, jitted jnp twin elsewhere), and it runs
STAGED:

  * stage 1 — the same lune predicate restricted to each endpoint's
    ``stage1_k`` nearest neighbours (default 2).  The nearest neighbours are
    by far the most likely lune occupants, so this kills ~90% of candidates
    for ~13% of the arithmetic.
  * stage 2 — the full ``kmax-1``-list check on stage-1 survivors only.

Staging is EXACT, not approximate: stage 1 evaluates the identical formula
on a prefix of the same stored kNN lists, so its removals are a subset of
the full check's removals, and survivors get the full check anyway — the
final verdict equals the unstaged check bit-for-bit.

Tie robustness carries over verbatim from the unstaged check (core.rng):
own-list distances are read from the stored kNN pass (bit-exact for the
common structural tie) and a norm-scaled epsilon margin is added on the
"inside" side, so f32 noise can only KEEP an edge — the superset-safe
direction.

The exact-lune kernel (``lune_filter``) is the third member of the family:
``kernels.ops.lune_nonempty`` pads its edge list to the same pow2 buckets so
the whole cascade compiles one shape-stable program per (tier, k, d) — see
``engine.plan.cached_program``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import COMPILER_PARAMS as _COMPILER_PARAMS

_EPS = 64.0 * 1.1920929e-07


# ---------------------------------------------------------------------------
# jnp twin (CPU benchmarks + parity oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_check", "chunk"))
def _edge_cascade_jnp(x, cd2k, knn_idx, knn_d2, ea, eb, valid, *, k_check, chunk):
    """Fused cascade over an edge list, chunked to bound the working set.

    Returns ``(killed, certified, d2_e, w2)`` — ``killed`` is the kNN-lune
    verdict over each endpoint's first ``k_check`` stored neighbours,
    ``certified`` marks edges provably in the exact RNG (w == max core
    dist).  Invalid slots read index 0 and return garbage; callers mask.
    """
    eps = jnp.float32(_EPS)
    kidx = knn_idx[:, :k_check]
    kd2 = knn_d2[:, :k_check]

    def one_chunk(args):
        ea_c, eb_c = args
        xa = x[ea_c].astype(jnp.float32)
        xb = x[eb_c].astype(jnp.float32)
        diff = xa - xb
        d2_e = jnp.sum(diff * diff, axis=-1)
        cda_s = cd2k[ea_c]
        cdb_s = cd2k[eb_c]
        w2 = jnp.maximum(jnp.maximum(cda_s, cdb_s), d2_e)
        certified = w2 == jnp.maximum(cda_s, cdb_s)

        cand_a = kidx[ea_c]                                          # (c, k)
        cand_b = kidx[eb_c]
        xca = x[cand_a].astype(jnp.float32)                          # (c, k, d)
        xcb = x[cand_b].astype(jnp.float32)
        # own-list distances come from storage; cross distances are recomputed
        d2a_ca = kd2[ea_c]
        d2b_cb = kd2[eb_c]
        d2b_ca = jnp.sum((xb[:, None, :] - xca) ** 2, -1)
        d2a_cb = jnp.sum((xa[:, None, :] - xcb) ** 2, -1)

        cda = cda_s[:, None]
        cdb = cdb_s[:, None]
        an = jnp.sum(xa * xa, -1)[:, None]
        bn = jnp.sum(xb * xb, -1)[:, None]
        w2c = w2[:, None]

        def inside(cand, xc, d2ac, d2bc):
            cdc = cd2k[cand]
            cn = jnp.sum(xc * xc, -1)
            mrd_ac = jnp.maximum(jnp.maximum(d2ac, cda), cdc) + eps * (an + cn)
            mrd_bc = jnp.maximum(jnp.maximum(d2bc, cdb), cdc) + eps * (bn + cn)
            not_ep = (cand != ea_c[:, None]) & (cand != eb_c[:, None])
            return jnp.any(
                (jnp.maximum(mrd_ac, mrd_bc) < w2c) & not_ep, axis=1
            )

        killed = inside(cand_a, xca, d2a_ca, d2b_ca) | inside(
            cand_b, xcb, d2a_cb, d2b_cb
        )
        return killed, certified, d2_e, w2

    m = ea.shape[0]
    c = min(chunk, m)
    m_pad = -(-m // c) * c
    pad = lambda v: jnp.concatenate(  # noqa: E731
        [v, jnp.zeros((m_pad - m,), v.dtype)]
    )
    ea_p = jnp.where(valid, ea, 0).astype(jnp.int32)
    eb_p = jnp.where(valid, eb, 0).astype(jnp.int32)
    killed, certified, d2_e, w2 = jax.lax.map(
        one_chunk, (pad(ea_p).reshape(-1, c), pad(eb_p).reshape(-1, c))
    )
    out = lambda v: v.reshape(m_pad)[:m]  # noqa: E731
    return out(killed) & valid, out(certified) & valid, out(d2_e), out(w2)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _edge_cascade_kernel(
    ax_ref,      # (be, d)   endpoint a coordinates
    bx_ref,      # (be, d)   endpoint b coordinates
    acd_ref,     # (be, 1)   cd2_kmax(a)
    bcd_ref,     # (be, 1)   cd2_kmax(b)
    aidx_ref,    # (be, 1)   global index of a
    bidx_ref,    # (be, 1)   global index of b
    ca_idx_ref,  # (be, k)   a's kNN candidate indices
    cb_idx_ref,  # (be, k)   b's kNN candidate indices
    ca_d2_ref,   # (be, k)   stored d2(a, cand_a) (own-list distances)
    cb_d2_ref,   # (be, k)   stored d2(b, cand_b)
    ca_x_ref,    # (be, k*d) cand_a coordinates, flattened
    cb_x_ref,    # (be, k*d) cand_b coordinates, flattened
    ca_cd_ref,   # (be, k)   cd2_kmax(cand_a)
    cb_cd_ref,   # (be, k)   cd2_kmax(cand_b)
    killed_ref,  # (be, 1)   out: int32 lune verdict
    cert_ref,    # (be, 1)   out: int32 certificate
    d2_ref,      # (be, 1)   out: f32 squared edge length
    w2_ref,      # (be, 1)   out: f32 squared mrd_kmax weight
    *,
    k: int,
    d: int,
):
    a = ax_ref[...].astype(jnp.float32)
    b = bx_ref[...].astype(jnp.float32)
    diff = a - b
    d2_e = jnp.sum(diff * diff, axis=-1, keepdims=True)              # (be, 1)
    cda = acd_ref[...]
    cdb = bcd_ref[...]
    w2 = jnp.maximum(jnp.maximum(cda, cdb), d2_e)
    cert_ref[...] = (w2 == jnp.maximum(cda, cdb)).astype(jnp.int32)
    d2_ref[...] = d2_e
    w2_ref[...] = w2

    eps = jnp.float32(_EPS)
    an = jnp.sum(a * a, -1, keepdims=True)
    bn = jnp.sum(b * b, -1, keepdims=True)
    ai = aidx_ref[...]
    bi = bidx_ref[...]

    killed = jnp.zeros(w2.shape, jnp.int32)
    # unrolled over the (static, small) candidate count — each step is pure
    # (be, d)/(be, 1) VPU work, so everything stays in-register
    for side in range(2):
        own_x, own_cd, own_n = (a, cda, an) if side == 0 else (b, cdb, bn)
        oth_x, oth_cd, oth_n = (b, cdb, bn) if side == 0 else (a, cda, an)
        ci_ref = ca_idx_ref if side == 0 else cb_idx_ref
        cd2_ref_ = ca_d2_ref if side == 0 else cb_d2_ref
        cx_ref = ca_x_ref if side == 0 else cb_x_ref
        ccd_ref = ca_cd_ref if side == 0 else cb_cd_ref
        for j in range(k):
            xc = cx_ref[:, j * d : (j + 1) * d].astype(jnp.float32)  # (be, d)
            cn = jnp.sum(xc * xc, -1, keepdims=True)
            cdc = ccd_ref[:, j : j + 1]
            d2_own = cd2_ref_[:, j : j + 1]                # stored own-list d2
            dob = oth_x - xc
            d2_oth = jnp.sum(dob * dob, -1, keepdims=True)
            mrd_own = jnp.maximum(jnp.maximum(d2_own, own_cd), cdc) + eps * (own_n + cn)
            mrd_oth = jnp.maximum(jnp.maximum(d2_oth, oth_cd), cdc) + eps * (oth_n + cn)
            cj = ci_ref[:, j : j + 1]
            not_ep = (cj != ai) & (cj != bi)
            inside = (jnp.maximum(mrd_own, mrd_oth) < w2) & not_ep
            killed = killed | inside.astype(jnp.int32)
    killed_ref[...] = killed


def _edge_cascade_pallas(
    x, cd2k, knn_idx, knn_d2, ea, eb, valid, *, k_check, block_e, interpret
):
    """Pallas dispatch of the fused cascade: gathers feed fixed tiles, the
    kernel fuses all per-edge arithmetic."""
    m = ea.shape[0]
    n, d = x.shape
    be = min(block_e, max(8, m))
    m_pad = -(-m // be) * be

    ea_i = jnp.where(valid, ea, 0).astype(jnp.int32)
    eb_i = jnp.where(valid, eb, 0).astype(jnp.int32)

    def padm(v, fill=0):
        return jnp.full((m_pad,) + v.shape[1:], fill, v.dtype).at[:m].set(v)

    kidx = knn_idx[:, :k_check]
    kd2 = knn_d2[:, :k_check]
    ca = kidx[ea_i]
    cb = kidx[eb_i]
    args = (
        padm(x[ea_i].astype(jnp.float32)),
        padm(x[eb_i].astype(jnp.float32)),
        padm(cd2k[ea_i])[:, None],
        padm(cd2k[eb_i])[:, None],
        padm(ea_i, -1)[:, None],
        padm(eb_i, -1)[:, None],
        padm(ca, -1),
        padm(cb, -1),
        padm(kd2[ea_i]),
        padm(kd2[eb_i]),
        padm(x[ca].astype(jnp.float32).reshape(m, k_check * d)),
        padm(x[cb].astype(jnp.float32).reshape(m, k_check * d)),
        padm(cd2k[ca]),
        padm(cd2k[cb]),
    )
    grid = (m_pad // be,)
    espec = lambda w: pl.BlockSpec((be, w), lambda i: (i, 0))  # noqa: E731
    widths = (d, d, 1, 1, 1, 1, k_check, k_check, k_check, k_check,
              k_check * d, k_check * d, k_check, k_check)
    kernel = functools.partial(_edge_cascade_kernel, k=k_check, d=d)
    killed, cert, d2_e, w2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[espec(w) for w in widths],
        out_specs=[espec(1)] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return (
        killed[:m, 0].astype(bool) & valid,
        cert[:m, 0].astype(bool) & valid,
        d2_e[:m, 0],
        w2[:m, 0],
    )


_SENTINEL_I32 = jnp.iinfo(jnp.int32).max


@functools.partial(jax.jit, static_argnames=("k_check", "chunk"))
def stage1_packed(x, cd2k, knn_idx, knn_d2, ks, n_pack, *, k_check, chunk):
    """Whole stage-1 block as ONE program (jnp backends): unpack sorted keys,
    run the fused cascade, split survivors on the certificate.

    Returns ``(lo, hi, d2, w2, surv_cert, surv_open, n_cert, n_open)``.
    """
    valid = ks != _SENTINEL_I32
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    safe = jnp.where(valid, ks, 0)
    lo = (safe // n_pack).astype(jnp.int32)
    hi = (safe % n_pack).astype(jnp.int32)
    killed, cert, d2_e, w2 = _edge_cascade_jnp(
        x, cd2k, knn_idx, knn_d2, lo, hi, valid, k_check=k_check, chunk=chunk
    )
    surv = valid & first & ~killed
    surv_cert = surv & cert
    surv_open = surv & ~cert
    return (
        lo, hi, d2_e, w2, surv_cert, surv_open,
        jnp.sum(surv_cert), jnp.sum(surv_open),
    )


def edge_cascade(
    x: jax.Array,
    cd2k: jax.Array,
    knn_idx: jax.Array,
    knn_d2: jax.Array,
    ea: jax.Array,
    eb: jax.Array,
    valid: jax.Array,
    *,
    k_check: int,
    backend: str = "jnp",
    chunk: int = 65536,
    block_e: int = 256,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused per-edge cascade, dispatched per backend.

    Returns device arrays ``(killed, certified, d2_e, w2)``; invalid slots
    are masked False in the boolean outputs and hold garbage floats.
    """
    if backend in ("pallas", "pallas_interpret"):
        return _edge_cascade_pallas(
            x, cd2k, knn_idx, knn_d2, ea, eb, valid,
            k_check=k_check, block_e=block_e,
            interpret=backend == "pallas_interpret",
        )
    return _edge_cascade_jnp(
        x, cd2k, knn_idx, knn_d2, ea, eb, valid, k_check=k_check, chunk=chunk
    )
