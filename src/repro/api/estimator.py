"""`MultiHDBSCAN`: sklearn-style front door over a :class:`FittedModel`.

One ``fit`` buys the whole mpts range (the paper's "hundred hierarchies for
the cost of ~2 HDBSCAN* runs").  Since the FittedModel artifact layer, the
estimator is a thin sklearn-compatible wrapper: ``fit`` builds a
``FittedModel`` (reachable as ``est.model_``) and every query delegates to
it — ``est.model_.select(mpts, policy)`` is the first-class query surface,
and ``est.model_.save(path)`` / ``FittedModel.load(path)`` move the fitted
state between processes without a refit.

The original per-level accessors (``labels_for`` / ``hierarchy_for`` /
``membership_for`` / ``probabilities_for``) remain as deprecation shims for
one release: they answer exactly as before but emit a ``FutureWarning``
pointing at the ``select`` surface.
"""

from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Sequence

import numpy as np

from ..core import multi, predict
from .model import FittedModel
from .selection import SelectionPolicy


@dataclasses.dataclass
class Membership:
    """Per-fitted-point view of one density level: labels + strengths."""

    mpts: int
    labels: np.ndarray         # (n,) int64, -1 = noise
    probabilities: np.ndarray  # (n,) float64 in [0, 1], 0 for noise
    lambdas: np.ndarray        # (n,) float64 departure lambda (0 for noise)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"MultiHDBSCAN.{old} is deprecated and will be removed next release; "
        f"use {new} instead",
        FutureWarning,
        stacklevel=3,
    )


class MultiHDBSCAN:
    """All HDBSCAN* hierarchies for mpts in [kmin, kmax] from one fit.

    Parameters
    ----------
    kmax : int
        Largest mpts (neighbourhood size) in the range; one (kmax-1)-NN pass
        and one RNG^kmax serve the whole range.
    kmin : int
        Smallest mpts in the range (default 2).
    mpts_values : sequence of int, optional
        Explicit subset of the range to compute MSTs for (default: all of
        [kmin, kmax]).
    min_cluster_size : int, optional
        Condensation threshold; default per-mpts ``max(2, mpts)``.
    cluster_selection_method : {"eom", "leaf"}
        Excess-of-mass (HDBSCAN* default) or condensed-tree leaves.
    cluster_selection_epsilon : float
        Malzer & Baum's hybrid threshold: selected clusters born at a
        distance below epsilon merge upward into their first epsilon-stable
        ancestor.  0.0 (default) disables it.
    allow_single_cluster : bool
        Permit the root as a selected cluster.
    variant : {"rng_ss", "rng_star", "rng"}
        RNG^kmax graph variant (paper §IV); rng_star is the default
        speed/size tradeoff.
    backend : str, optional
        Kernel backend ("pallas", "pallas_interpret", "jnp", "ref");
        default auto-selects per platform.
    mesh : jax.sharding.Mesh, optional
        Device mesh for the sharded execution engine.  When the mesh has a
        non-trivial ``data`` axis the row-parallel stages (kNN, exact lune
        scan, the per-mpts Borůvka range) shard over it; a 1-device mesh
        (or ``None``) degrades to the single-device path, so the SAME user
        code runs on a laptop and a pod (``dist.sharding`` resolve-rules
        philosophy).
    plan : "auto" | "single" | "mesh" | engine.Plan
        Placement request, resolved once at ``fit`` against ``mesh``:
        "auto" shards iff the mesh is usable, "single" forces the local
        path, "mesh" errors rather than silently degrading.  Pass a
        pre-built ``engine.Plan`` to pin every chunk/tile size explicitly.
    max_cached_hierarchies : int, optional
        Bound on the per-(mpts, policy) extraction cache (LRU eviction).
        ``None`` (default) keeps every requested level — right for
        exploration; long-lived serving processes
        (``serve.ClusterServeEngine``) set a bound so a hostile query mix
        cannot hold all R condensed trees resident.
    """

    def __init__(
        self,
        kmax: int = 16,
        *,
        kmin: int = 2,
        mpts_values: Sequence[int] | None = None,
        min_cluster_size: int | None = None,
        cluster_selection_method: str = "eom",
        cluster_selection_epsilon: float = 0.0,
        allow_single_cluster: bool = False,
        variant: str = "rng_star",
        backend: str | None = None,
        mesh=None,
        plan: "engine.Plan | str" = "auto",
        max_cached_hierarchies: int | None = None,
    ):
        if cluster_selection_method not in ("eom", "leaf"):
            raise ValueError(
                "cluster_selection_method must be 'eom' or 'leaf'; "
                f"got {cluster_selection_method!r}"
            )
        if kmax < 2:
            raise ValueError(f"kmax must be >= 2; got {kmax}")
        multi._validate_min_cluster_size(min_cluster_size)
        if not 2 <= kmin <= kmax:
            raise ValueError(f"need 2 <= kmin <= kmax; got kmin={kmin}, kmax={kmax}")
        self.kmax = kmax
        self.kmin = kmin
        self.mpts_values = list(mpts_values) if mpts_values is not None else None
        self.min_cluster_size = min_cluster_size
        self.cluster_selection_method = cluster_selection_method
        self.cluster_selection_epsilon = cluster_selection_epsilon
        self.allow_single_cluster = allow_single_cluster
        self.variant = variant
        self.backend = backend
        self.mesh = mesh
        self.plan = plan
        if max_cached_hierarchies is not None and max_cached_hierarchies < 1:
            raise ValueError(
                f"max_cached_hierarchies must be >= 1 or None; "
                f"got {max_cached_hierarchies}"
            )
        self._max_cached_hierarchies = max_cached_hierarchies
        self._model: FittedModel | None = None
        # eager policy construction: bad selection knobs fail HERE, not at fit
        self._selection_policy()

    def _selection_policy(self) -> SelectionPolicy:
        """The estimator's configuration as a SelectionPolicy."""
        return SelectionPolicy(
            method=self.cluster_selection_method,
            epsilon=self.cluster_selection_epsilon,
            allow_single_cluster=self.allow_single_cluster,
            min_cluster_size=self.min_cluster_size,
        )

    # -- fitting -----------------------------------------------------------

    def fit(self, X) -> "MultiHDBSCAN":
        """Compute the shared graph and every per-mpts MST (no extraction)."""
        # refit hygiene: clear every fitted (trailing-underscore) attribute
        # from a prior fit/fit_predict FIRST, so a failed refit can't leave
        # a half-stale estimator (e.g. labels_ from the previous dataset)
        for name in [
            k for k in list(vars(self)) if k.endswith("_") and not k.startswith("_")
        ]:
            delattr(self, name)
        self._model = None
        self._model = FittedModel.fit(
            X,
            self.kmax,
            kmin=self.kmin,
            mpts_values=self.mpts_values,
            policy=self._selection_policy(),
            variant=self.variant,
            backend=self.backend,
            mesh=self.mesh,
            plan=self.plan,
            max_cached_hierarchies=self._max_cached_hierarchies,
        )
        self.plan_ = self._model.plan
        self.n_features_in_ = self._model.n_features
        self.n_samples_ = self._model.n_samples
        self.mpts_values_ = self._model.mpts_values
        self.timings_ = dict(self._model.msts.timings)
        return self

    def fit_predict(self, X, mpts: int | None = None) -> np.ndarray:
        """fit + labels at one density level (default: the largest, kmax)."""
        self.fit(X)
        labels = self.model_.select(
            mpts if mpts is not None else self.mpts_values_[-1]
        ).labels
        self.labels_ = labels
        return labels

    # -- the new surface ---------------------------------------------------

    @property
    def model_(self) -> FittedModel:
        """The fitted artifact: ``select`` / ``select_all`` / ``save`` live here."""
        if self._model is None:
            raise RuntimeError(
                "MultiHDBSCAN instance is not fitted yet; call fit(X)"
            )
        return self._model

    def select(self, mpts: int, policy: SelectionPolicy | None = None):
        """The :class:`~repro.api.model.Clustering` view at one density level."""
        return self.model_.select(mpts, policy)

    def select_all(self, policy: SelectionPolicy | None = None):
        """Every fitted density level (one batched device linkage pass)."""
        return self.model_.select_all(policy)

    def save(self, path: str) -> str:
        """Persist the fitted state as an artifact (``FittedModel.save``)."""
        return self.model_.save(path)

    # -- legacy internal surface (kept for compatibility) ------------------

    @property
    def max_cached_hierarchies(self) -> int | None:
        return self._max_cached_hierarchies

    @max_cached_hierarchies.setter
    def max_cached_hierarchies(self, value: int | None) -> None:
        if value is not None and value < 1:
            raise ValueError(
                f"max_cached_hierarchies must be >= 1 or None; got {value}"
            )
        self._max_cached_hierarchies = value
        if self._model is not None:
            self._model.max_cached_hierarchies = value

    @property
    def _msts(self) -> multi.MultiMSTResult | None:
        return None if self._model is None else self._model.msts

    @property
    def _X(self) -> np.ndarray | None:
        return None if self._model is None else self._model.X

    @property
    def _linkage(self) -> multi.LinkageRange | None:
        return None if self._model is None else self._model._linkage

    @property
    def _hierarchy_cache(self) -> "collections.OrderedDict[int, multi.HierarchyResult]":
        """Legacy view of the model's cache: default-policy entries by mpts."""
        if self._model is None:
            return collections.OrderedDict()
        default = self._model.default_policy
        return collections.OrderedDict(
            (mpts, h)
            for (mpts, pol), h in self._model._cache.items()
            if pol == default
        )

    @property
    def _walk_cache(self) -> dict[int, predict.WalkTable]:
        if self._model is None:
            return {}
        return self._model._walk_cache(self._model.default_policy)

    def _check_fitted(self) -> multi.MultiMSTResult:
        return self.model_.msts

    def _ensure_linkage(self) -> multi.LinkageRange:
        return self.model_._ensure_linkage()

    # -- deprecated per-level accessors (one release of FutureWarning) -----

    def hierarchy_for(self, mpts: int) -> multi.HierarchyResult:
        """Deprecated: use ``est.model_.select(mpts).hierarchy``."""
        _deprecated("hierarchy_for(mpts)", "model_.select(mpts).hierarchy")
        return self.model_.hierarchy(mpts)

    def labels_for(self, mpts: int) -> np.ndarray:
        """Deprecated: use ``est.model_.select(mpts).labels``."""
        _deprecated("labels_for(mpts)", "model_.select(mpts).labels")
        return self.model_.hierarchy(mpts).labels

    def membership_for(self, mpts: int) -> Membership:
        """Deprecated: use ``est.model_.select(mpts)`` (same fields)."""
        _deprecated("membership_for(mpts)", "model_.select(mpts)")
        c = self.model_.select(mpts)
        return Membership(
            mpts=mpts,
            labels=c.labels,
            probabilities=c.probabilities,
            lambdas=c.lambdas,
        )

    def probabilities_for(self, mpts: int) -> np.ndarray:
        """Deprecated: use ``est.model_.select(mpts).probabilities``."""
        _deprecated("probabilities_for(mpts)", "model_.select(mpts).probabilities")
        return self.model_.select(mpts).probabilities

    # -- stable query surface (delegates to the model) ----------------------

    def approximate_predict(
        self,
        Q,
        mpts: int | None = None,
        policy: SelectionPolicy | None = None,
    ) -> "tuple[np.ndarray, np.ndarray] | predict.PredictResult":
        """Out-of-sample assignment of a query batch (no refit).

        One device pass ranks the batch against the fitted points and
        attaches every query for EVERY fitted mpts row at once; the cached
        condensed trees then supply labels and membership probabilities per
        level (McInnes & Healy's ``approximate_predict``, batched across
        the density range).

        With ``mpts`` given, returns ``(labels, probabilities)`` for that
        level (hdbscan-style).  With ``mpts=None``, returns the full
        :class:`~repro.core.predict.PredictResult` — (R, q) labels /
        probabilities / lambdas / attachment neighbours.  ``policy``
        overrides the estimator's selection configuration per call.
        """
        return self.model_.approximate_predict(Q, mpts, policy)

    def dbcv_profile(self) -> list[dict]:
        """DBCV relative validity at every fitted density level.

        The paper's §I motivation as one query: an internal validity score
        per mpts (computed on the per-mpts mutual-reachability MST, the
        standard fast approximation), so callers can rank density levels
        without ground truth.  Returns ``[{"mpts", "dbcv", "n_clusters"}]``.
        """
        return self.model_.dbcv_profile()

    def mst_for(self, mpts: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(ea, eb, w) MST edges under mutual reachability at this mpts."""
        return self.model_.mst(mpts)

    @property
    def graph_(self):
        """The fitted RNG^kmax (RngGraph: edges, d2, variant, stats)."""
        return self.model_.graph

    @property
    def n_graph_edges_(self) -> int:
        """Edge count of the shared RNG^kmax (vs n(n-1)/2 for the baseline)."""
        return self.model_.n_graph_edges

    def mpts_profile(self) -> list[dict]:
        """Stability-across-mpts summary: one row per density level.

        Each row reports how the clustering looks at that mpts — the paper's
        multi-density exploration ("which density level reveals which
        cluster") as a single query.  ``total_stability`` sums selected-
        cluster excess-of-mass; comparisons across mpts are indicative (the
        lambda scale shifts with density), so treat it as a ranking aid, not
        an absolute score.
        """
        return self.model_.mpts_profile()

    def __repr__(self) -> str:
        fitted = "" if self._model is None else f", fitted n={self.n_samples_}"
        place = ""
        if getattr(self, "plan_", None) is not None:
            place = f", plan={self.plan_.describe()}"
        return (
            f"MultiHDBSCAN(kmax={self.kmax}, kmin={self.kmin}, "
            f"variant={self.variant!r}, "
            f"cluster_selection_method={self.cluster_selection_method!r}"
            f"{place}{fitted})"
        )
