"""Property-based metric checks (hypothesis; skipped if not installed).

  * mrd symmetry + triangle inequality (Thm 1's prerequisites)
  * core-distance monotonicity in mpts (Thm 2's prerequisite)
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ref as oref  # noqa: E402


@st.composite
def point_sets(draw):
    n = draw(st.integers(12, 40))
    d = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.normal(scale=draw(st.floats(0.5, 10.0)), size=(n, d))


@given(point_sets(), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_mrd_metric_properties(x, mpts):
    mpts = min(mpts, len(x))
    m = oref.mrd_matrix(x, mpts)
    # symmetry
    np.testing.assert_allclose(m, m.T)
    # triangle inequality (Thm 1 proof): mrd(a,c) <= mrd(a,b) + mrd(b,c)
    lhs = m[:, None, :]                      # (a, 1, c)
    rhs = m[:, :, None] + m[None, :, :]      # (a, b) + (b, c)
    assert (lhs <= rhs + 1e-9).all()


@given(point_sets())
@settings(max_examples=15, deadline=None)
def test_core_distance_monotone(x):
    kmax = min(10, len(x))
    cd = oref.core_distances(x, kmax)
    assert (np.diff(cd, axis=1) >= -1e-12).all()
