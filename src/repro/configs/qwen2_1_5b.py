"""qwen2-1.5b [dense] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

QKV bias, SwiGLU, head_dim 128.  [arXiv:2407.10671; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    arch="transformer",
    vocab=151936,
    d_model=1536,
    n_layers=28,
    n_heads=12,
    n_kv=2,
    d_head=128,
    d_ff=8960,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    run_long_500k=False,
    skip_note="pure full attention; long_500k skipped per task rule",
)
