"""Roofline HLO analyzer: validated against a program with KNOWN flops."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import hlo_utils


def test_scan_flops_counted_with_trip_count():
    """L matmuls inside a scan must count L times (cost_analysis counts 1)."""
    L, M, K, N = 7, 64, 128, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jnp.zeros((L, K, K), jnp.float32)
    x = jnp.zeros((M, K), jnp.float32)
    compiled = jax.jit(f).lower(ws, x).compile()
    stats = hlo_utils.analyze_hlo(compiled.as_text())
    want = 2 * M * K * K * L
    assert stats.unknown_trip_counts == 0
    # tanh etc add nothing to dot flops; tolerance for XLA rewrites
    assert 0.9 * want <= stats.flops <= 1.3 * want, (stats.flops, want)


def test_plain_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    stats = hlo_utils.analyze_hlo(compiled.as_text())
    want = 2 * 128 * 256 * 64
    assert 0.99 * want <= stats.flops <= 1.01 * want


def test_bytes_scale_with_sizes():
    def f(x):
        return (x * 2.0 + 1.0).sum()

    small = jax.jit(f).lower(jnp.zeros((1000,))).compile()
    big = jax.jit(f).lower(jnp.zeros((100000,))).compile()
    s1 = hlo_utils.analyze_hlo(small.as_text()).bytes_hbm
    s2 = hlo_utils.analyze_hlo(big.as_text()).bytes_hbm
    assert s2 > 10 * s1


def test_roofline_terms_shape():
    stats = hlo_utils.HloStats(flops=197e12, bytes_hbm=819e9, coll_bytes={"all-reduce": 49.5e9})
    t = hlo_utils.roofline_terms(stats, 1)
    np.testing.assert_allclose(t["t_compute_s"], 1.0)
    np.testing.assert_allclose(t["t_memory_s"], 1.0)
    np.testing.assert_allclose(t["t_collective_s"], 1.0)
