"""Decoder-only transformer covering the dense / MoE / MLA / VLM archs.

One scan-over-layers body handles every per-layer variation through scanned
*data* rather than structural branches:
  * mixed local:global attention (gemma3) — per-layer (window, rope_theta)
    arrays are scan xs; the mask math treats window<=0 as unbounded.
  * GQA/MQA — head replication handled inside layers.attention.
  * MLA (deepseek) and MoE (deepseek, kimi) — selected statically per config
    (uniform across layers, so the scan body stays structure-uniform).

Params are plain pytrees; ``init`` returns (params, specs) where specs hold
logical axis names per dim (see dist/sharding.py).  ``abstract_params`` gives
ShapeDtypeStructs via eval_shape — the dry-run never allocates weights.

KV cache layout (decode): single stacked arrays (L, B, Smax, Hkv, Dh) carried
through the layer scan and updated in place with dynamic_update_slice — keeps
the HLO compact and lets XLA alias the buffers (donated in serve_step).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers as L


@jax.custom_vjp
def _residual_barrier(x):
    """optimization_barrier with an explicit gradient rule (the primitive has
    no differentiation rule on some jax versions); barrier both passes."""
    return jax.lax.optimization_barrier(x)


def _residual_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _residual_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


def _layer_windows_py(cfg) -> list[int]:
    """Per-layer window sizes: 0 => full causal. Pure python (safe under
    eval_shape tracing)."""
    w = []
    for i in range(cfg.n_layers):
        if cfg.window and cfg.window_period and (i + 1) % cfg.window_period == 0:
            w.append(0)                     # global layer
        elif cfg.window:
            w.append(cfg.window)
        else:
            w.append(0)
    return w


def _layer_windows(cfg, s_ref: int) -> jnp.ndarray:
    return jnp.asarray(_layer_windows_py(cfg), jnp.int32)


def _layer_thetas(cfg) -> jnp.ndarray:
    t = []
    for i in range(cfg.n_layers):
        if (
            cfg.rope_theta_global
            and cfg.window_period
            and (i + 1) % cfg.window_period == 0
        ):
            t.append(cfg.rope_theta_global)
        else:
            t.append(cfg.rope_theta)
    return jnp.asarray(t, jnp.float32)


def init(cfg, key) -> tuple[dict, dict]:
    ks = iter(jax.random.split(key, 64))
    d = cfg.d_model
    use_mla = cfg.kv_lora > 0
    use_moe = cfg.n_experts > 0
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}

    p["embed"], s["embed"] = L.dense_init(
        next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
    )
    if not cfg.tie_embeddings:
        p["unembed"], s["unembed"] = L.dense_init(
            next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
        )
    p["final_norm"], s["final_norm"] = L.rmsnorm_init(d)

    if cfg.frontend:
        p["proj_in"], s["proj_in"] = L.dense_init(
            next(ks), (cfg.frontend_dim, d), ("frontend", "embed"), jnp.float32
        )
        p["proj_mid"], s["proj_mid"] = L.dense_init(
            next(ks), (d, d), ("embed", "embed2"), jnp.float32
        )

    def stack(initfn, *args):
        """Init per-layer params and stack along a leading 'layers' dim."""
        base = next(ks)
        outs = [initfn(jax.random.fold_in(base, i), *args) for i in range(cfg.n_layers)]
        params = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        specs = jax.tree.map(lambda sp: ("layers",) + sp, outs[0][1],
                             is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v))
        return params, specs

    def attn_init(k):
        kk = jax.random.split(k, 5)
        ap, asp = {}, {}
        hq = cfg.n_heads * cfg.d_head
        hkv = cfg.n_kv * cfg.d_head
        ap["wq"], asp["wq"] = L.dense_init(kk[0], (d, hq), ("embed", "heads_dim"), jnp.float32)
        ap["wk"], asp["wk"] = L.dense_init(kk[1], (d, hkv), ("embed", "kv_dim"), jnp.float32)
        ap["wv"], asp["wv"] = L.dense_init(kk[2], (d, hkv), ("embed", "kv_dim"), jnp.float32)
        ap["wo"], asp["wo"] = L.dense_init(kk[3], (hq, d), ("heads_dim", "embed"), jnp.float32)
        if cfg.qkv_bias:
            ap["bq"], asp["bq"] = jnp.zeros((hq,), jnp.float32), ("heads_dim",)
            ap["bk"], asp["bk"] = jnp.zeros((hkv,), jnp.float32), ("kv_dim",)
            ap["bv"], asp["bv"] = jnp.zeros((hkv,), jnp.float32), ("kv_dim",)
        return ap, asp

    def block_init(k):
        kk = jax.random.split(k, 4)
        bp, bs = {}, {}
        bp["ln1"], bs["ln1"] = L.rmsnorm_init(d)
        bp["ln2"], bs["ln2"] = L.rmsnorm_init(d)
        if use_mla:
            bp["attn"], bs["attn"] = L.init_mla(kk[0], cfg)
        else:
            bp["attn"], bs["attn"] = attn_init(kk[0])
        if use_moe:
            bp["moe"], bs["moe"] = L.init_moe(kk[1], cfg)
        else:
            bp["mlp"], bs["mlp"] = L.init_mlp(kk[1], cfg, cfg.d_ff)
        return bp, bs

    p["layers"], s["layers"] = stack(block_init)
    return p, s


def abstract_init(init_fn, cfg):
    """(ShapeDtypeStruct params, logical-axis specs) with zero allocation.

    The specs are static python produced while tracing init under eval_shape
    (captured by closure side effect), so big configs never touch memory.
    """
    box = {}

    def go(key):
        params, specs = init_fn(cfg, key)
        box["specs"] = specs
        return params

    shapes = jax.eval_shape(go, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, box["specs"]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_block(pl, h, cfg, positions, theta, window, k_pos, kv_valid, cache_kv=None):
    """Standard GQA attention. Returns (out, (k_new, v_new)) for caching."""
    b, sq, d = h.shape
    dt = h.dtype
    ap = pl["attn"]
    q = h @ ap["wq"].astype(dt)
    k = h @ ap["wk"].astype(dt)
    v = h @ ap["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + ap["bq"].astype(dt)
        k = k + ap["bk"].astype(dt)
        v = v + ap["bv"].astype(dt)
    q = q.reshape(b, sq, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, sq, cfg.n_kv, cfg.d_head)
    v = v.reshape(b, sq, cfg.n_kv, cfg.d_head)
    q = L.rope(q, positions[None, :], theta)
    k = L.rope(k, positions[None, :], theta)
    if cache_kv is not None:
        k_all, v_all = cache_kv
    else:
        k_all, v_all = k, v
    o = L.attention(
        q, k_all, v_all,
        q_pos=positions, k_pos=k_pos, window=window,
        softcap=0.0, kv_valid=kv_valid,
    )
    out = o.reshape(b, sq, cfg.n_heads * cfg.d_head) @ ap["wo"].astype(dt)
    return out, (k, v)


def _mla_block(pl, h, cfg, positions, k_pos, kv_valid, cache_latent=None):
    b, sq, d = h.shape
    dt = h.dtype
    ap = pl["attn"]
    q, ckv, k_rope = L.mla_qkv(ap, h, positions, cfg)
    if cache_latent is not None:
        ckv_all, kr_all = cache_latent
    else:
        ckv_all, kr_all = ckv, k_rope
    k, v = L.mla_expand_kv(ap, ckv_all, kr_all, cfg, dt)
    # pad V up to the qk head dim for the shared attention primitive, then slice
    o = L.attention(
        q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - v.shape[-1]))),
        q_pos=positions, k_pos=k_pos, window=0, kv_valid=kv_valid,
    )[..., : cfg.v_head]
    out = o.reshape(b, sq, cfg.n_heads * cfg.v_head) @ ap["wo"].astype(dt)
    return out, (ckv, k_rope)


def embed_inputs(p, cfg, tokens, patch_embeds=None):
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[tokens]
    if cfg.frontend and patch_embeds is not None:
        pe = patch_embeds.astype(dt) @ p["proj_in"].astype(dt)
        pe = jax.nn.gelu(pe) @ p["proj_mid"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward(p, cfg, tokens, patch_embeds=None):
    """Full-sequence forward -> final hidden states (B, S, D) and aux loss."""
    x = embed_inputs(p, cfg, tokens, patch_embeds)
    b, s_len, d = x.shape
    positions = jnp.arange(s_len, dtype=jnp.int32)
    windows = _layer_windows(cfg, s_len)
    thetas = _layer_thetas(cfg)
    use_mla = cfg.kv_lora > 0
    use_moe = cfg.n_experts > 0

    def body(carry, xs):
        x, aux = carry
        # barrier: stops XLA from hoisting the rmsnorm f32 upcast out of the
        # backward loop as a full-residual-stack convert (10+ GiB at scale)
        x = _residual_barrier(x)
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        pl, w, th = xs
        h = L.rmsnorm(x, pl["ln1"])
        if use_mla:
            attn_out, _ = _mla_block(pl, h, cfg, positions, positions, None)
        else:
            attn_out, _ = _attn_block(pl, h, cfg, positions, th, w, positions, None)
        x = x + attn_out
        h2 = L.rmsnorm(x, pl["ln2"])
        if use_moe:
            mo, a = L.moe(pl["moe"], h2, cfg)
            x = x + mo
            aux = aux + a
        else:
            x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return (x, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), (p["layers"], windows, thetas))
    x = L.rmsnorm(x, p["final_norm"])
    return x, aux


def logits_fn(p, cfg, x):
    dt = x.dtype
    emb = p["embed"] if cfg.tie_embeddings else p["unembed"]
    logits = x @ emb.astype(dt).T
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def _cache_layout(cfg, max_len: int):
    """Static split of layers into ring-buffer (local window) vs full-length
    (global) cache groups.  §Perf hillclimb 2: a 1024-window local layer
    holding a 524288-slot cache is pure HBM burn — 28/34 of gemma3's
    long_500k cache; starcoder2's decode cache shrinks 8x the same way."""
    windows = _layer_windows_py(cfg)
    is_local = [0 < w < max_len for w in windows]
    loc_idx, glob_idx = [], []
    nl = ng = 0
    for ll in is_local:
        loc_idx.append(nl if ll else 0)
        glob_idx.append(0 if ll else ng)
        nl += int(ll)
        ng += int(not ll)
    win = min(cfg.window if cfg.window else max_len, max_len)
    return is_local, loc_idx, glob_idx, nl, ng, max(win, 1)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Abstract-or-concrete KV cache pytree (ring buffers for local layers)."""
    if cfg.kv_lora > 0:
        return {
            "ckv": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora), dtype),
            "kr": jnp.zeros((cfg.n_layers, batch, max_len, cfg.qk_rope), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    _, _, _, nl, ng, win = _cache_layout(cfg, max_len)
    hkv, dh = cfg.n_kv, cfg.d_head
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if nl:
        cache["k_loc"] = jnp.zeros((nl, batch, win, hkv, dh), dtype)
        cache["v_loc"] = jnp.zeros((nl, batch, win, hkv, dh), dtype)
        cache["kpos_loc"] = jnp.full((win,), -(2**30), jnp.int32)
    if ng:
        cache["k"] = jnp.zeros((ng, batch, max_len, hkv, dh), dtype)
        cache["v"] = jnp.zeros((ng, batch, max_len, hkv, dh), dtype)
    return cache


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def decode_step(p, cfg, cache, cur_tokens):
    """One decode step. cur_tokens: (B, 1). Returns (logits, new_cache).

    Local-window layers read/write a ring buffer (slot = pos % window);
    global layers keep the full-length cache.  The per-layer choice is
    STATIC (config), so homogeneous stacks skip the cond entirely.
    """
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = p["embed"].astype(dt)[cur_tokens]                        # (B, 1, D)
    positions = pos[None].astype(jnp.int32)                      # (1,)
    thetas = _layer_thetas(cfg)
    use_mla = cfg.kv_lora > 0
    use_moe = cfg.n_experts > 0

    if use_mla:
        max_len = cache["ckv"].shape[2]
        k_pos = jnp.arange(max_len, dtype=jnp.int32)
        kv_valid = k_pos <= pos

        def body(carry, xs):
            x, cache, li, aux = carry
            pl, th = xs
            h = L.rmsnorm(x, pl["ln1"])
            _, ckv_new, kr_new = L.mla_qkv(pl["attn"], h, positions, cfg)
            ckv_all = jax.lax.dynamic_update_slice(
                cache["ckv"][li], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
            kr_all = jax.lax.dynamic_update_slice(
                cache["kr"][li], kr_new.astype(cache["kr"].dtype), (0, pos, 0))
            cache = dict(
                cache,
                ckv=jax.lax.dynamic_update_index_in_dim(cache["ckv"], ckv_all, li, 0),
                kr=jax.lax.dynamic_update_index_in_dim(cache["kr"], kr_all, li, 0),
            )
            attn_out, _ = _mla_block(
                pl, h, cfg, positions, k_pos, kv_valid, (ckv_all, kr_all))
            x = x + attn_out
            h2 = L.rmsnorm(x, pl["ln2"])
            if use_moe:
                mo, a = L.moe(pl["moe"], h2, cfg)
                x = x + mo
                aux = aux + a
            else:
                x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
            return (x, cache, li + 1, aux), None

        (x, cache, _, _), _ = jax.lax.scan(
            body, (x, cache, jnp.int32(0), jnp.float32(0.0)),
            (p["layers"], thetas),
        )
        x = L.rmsnorm(x, p["final_norm"])
        cache = dict(cache, pos=pos + 1)
        return logits_fn(p, cfg, x)[:, 0], cache

    if "k" in cache:
        max_len = cache["k"].shape[2]
    else:
        # ring-only cache: any max_len strictly above the window reproduces
        # the layout the prefill used (if max_len == window the layer would
        # have been global and "k" would exist)
        max_len = cache["k_loc"].shape[2] + 1
    is_local, loc_idx, glob_idx, nl, ng, win = _cache_layout(cfg, max_len)
    windows = _layer_windows(cfg, max_len)

    if nl:
        slot = pos % win
        kpos_loc = cache["kpos_loc"].at[slot].set(pos)
        cache = dict(cache, kpos_loc=kpos_loc)
        loc_valid = kpos_loc >= 0
    if ng:
        k_pos_g = jnp.arange(cache["k"].shape[2], dtype=jnp.int32)
        g_valid = k_pos_g <= pos

    def attend_local(cache, pl, h, th, w, li_l):
        _, (k_new, v_new) = _attn_block(pl, h, cfg, positions, th, w, positions, None)
        k_all = jax.lax.dynamic_update_slice(
            cache["k_loc"][li_l], k_new.astype(cache["k_loc"].dtype), (0, slot, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v_loc"][li_l], v_new.astype(cache["v_loc"].dtype), (0, slot, 0, 0))
        cache = dict(
            cache,
            k_loc=jax.lax.dynamic_update_index_in_dim(cache["k_loc"], k_all, li_l, 0),
            v_loc=jax.lax.dynamic_update_index_in_dim(cache["v_loc"], v_all, li_l, 0),
        )
        out, _ = _attn_block(
            pl, h, cfg, positions, th, w, cache["kpos_loc"], loc_valid,
            (k_all.astype(dt), v_all.astype(dt)))
        return out, cache

    def attend_global(cache, pl, h, th, w, li_g):
        _, (k_new, v_new) = _attn_block(pl, h, cfg, positions, th, w, positions, None)
        k_all = jax.lax.dynamic_update_slice(
            cache["k"][li_g], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v"][li_g], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        cache = dict(
            cache,
            k=jax.lax.dynamic_update_index_in_dim(cache["k"], k_all, li_g, 0),
            v=jax.lax.dynamic_update_index_in_dim(cache["v"], v_all, li_g, 0),
        )
        out, _ = _attn_block(
            pl, h, cfg, positions, th, w, k_pos_g, g_valid,
            (k_all.astype(dt), v_all.astype(dt)))
        return out, cache

    def body(carry, xs):
        x, cache, aux = carry
        pl, th, w, is_loc, li_l, li_g = xs
        h = L.rmsnorm(x, pl["ln1"])
        if nl and ng:
            attn_out, cache = jax.lax.cond(
                is_loc,
                lambda c: attend_local(c, pl, h, th, w, li_l),
                lambda c: attend_global(c, pl, h, th, w, li_g),
                cache,
            )
        elif nl:
            attn_out, cache = attend_local(cache, pl, h, th, w, li_l)
        else:
            attn_out, cache = attend_global(cache, pl, h, th, w, li_g)
        x = x + attn_out
        h2 = L.rmsnorm(x, pl["ln2"])
        if use_moe:
            mo, a = L.moe(pl["moe"], h2, cfg)
            x = x + mo
            aux = aux + a
        else:
            x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return (x, cache, aux), None

    xs = (
        p["layers"], thetas, windows,
        jnp.asarray(is_local, bool),
        jnp.asarray(loc_idx, jnp.int32),
        jnp.asarray(glob_idx, jnp.int32),
    )
    (x, cache, _), _ = jax.lax.scan(body, (x, cache, jnp.float32(0.0)), xs)
    x = L.rmsnorm(x, p["final_norm"])
    cache = dict(cache, pos=pos + 1)
    return logits_fn(p, cfg, x)[:, 0], cache


def prefill(p, cfg, tokens, max_len: int, patch_embeds=None, cache_dtype=jnp.bfloat16):
    """Prefill a cache from a full prompt. Returns (last_logits, cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = embed_inputs(p, cfg, tokens, patch_embeds)
    b, s_len, d = x.shape
    positions = jnp.arange(s_len, dtype=jnp.int32)
    windows = _layer_windows(cfg, s_len)
    thetas = _layer_thetas(cfg)
    use_mla = cfg.kv_lora > 0
    use_moe = cfg.n_experts > 0

    def body(carry, xs):
        x, aux = carry
        pl, w, th = xs
        h = L.rmsnorm(x, pl["ln1"])
        if use_mla:
            attn_out, (ckv, kr) = _mla_block(pl, h, cfg, positions, positions, None)
            kv = (ckv.astype(cache_dtype), kr.astype(cache_dtype))
        else:
            attn_out, (k, v) = _attn_block(pl, h, cfg, positions, th, w, positions, None)
            kv = (k.astype(cache_dtype), v.astype(cache_dtype))
        x = x + attn_out
        h2 = L.rmsnorm(x, pl["ln2"])
        if use_moe:
            mo, a = L.moe(pl["moe"], h2, cfg)
            x = x + mo
            aux = aux + a
        else:
            x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return (x, aux), kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), kvs = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), (p["layers"], windows, thetas))
    x = L.rmsnorm(x, p["final_norm"])
    logits = logits_fn(p, cfg, x[:, -1:])
    pad = max_len - s_len
    if cfg.kv_lora > 0:
        cache = {
            "ckv": jnp.pad(kvs[0], ((0, 0), (0, 0), (0, pad), (0, 0))),
            "kr": jnp.pad(kvs[1], ((0, 0), (0, 0), (0, pad), (0, 0))),
            "pos": jnp.int32(s_len),
        }
    else:
        is_local, loc_idx, glob_idx, nl, ng, win = _cache_layout(cfg, max_len)
        cache = {"pos": jnp.int32(s_len)}
        loc_layers = [i for i, ll in enumerate(is_local) if ll]
        glob_layers = [i for i, ll in enumerate(is_local) if not ll]
        if ng:
            cache["k"] = jnp.pad(
                kvs[0][jnp.asarray(glob_layers)], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(
                kvs[1][jnp.asarray(glob_layers)], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        if nl:
            keep = min(win, s_len)
            p_sel = jnp.arange(s_len - keep, s_len)
            slots = p_sel % win
            k_l = kvs[0][jnp.asarray(loc_layers)]
            v_l = kvs[1][jnp.asarray(loc_layers)]
            zk = jnp.zeros((nl, b, win) + k_l.shape[3:], k_l.dtype)
            cache["k_loc"] = zk.at[:, :, slots].set(k_l[:, :, p_sel])
            cache["v_loc"] = zk.at[:, :, slots].set(v_l[:, :, p_sel])
            cache["kpos_loc"] = jnp.full((win,), -(2**30), jnp.int32).at[slots].set(
                p_sel.astype(jnp.int32))
        return logits[:, 0], cache
    return logits[:, 0], cache
