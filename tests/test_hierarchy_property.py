"""Property-based single-linkage checks (hypothesis; skipped if not installed)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import hierarchy, linkage  # noqa: E402


@st.composite
def spanning_edges(draw):
    n = draw(st.integers(5, 60))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # random spanning tree: connect each node to a random earlier node
    ea = np.array([rng.integers(0, i + 1) for i in range(n - 1)])
    eb = np.arange(1, n)
    w = rng.uniform(0.1, 5.0, size=n - 1)
    return n, ea, eb, w


@given(spanning_edges())
@settings(max_examples=30, deadline=None)
def test_single_linkage_matches_scipy(t):
    n, ea, eb, w = t
    Z = hierarchy.single_linkage(ea, eb, w, n)
    # merge DISTANCES multiset must equal edge weights, sizes must telescope.
    np.testing.assert_allclose(np.sort(Z[:, 2]), np.sort(w))
    assert Z[-1, 3] == n
    assert (Z[:, 3] >= 2).all()


@given(spanning_edges())
@settings(max_examples=20, deadline=None)
def test_batched_linkage_matches_reference(t):
    n, ea, eb, w = t
    w = w.astype(np.float32)
    Z_ref = hierarchy.single_linkage(ea, eb, w, n)
    left, right, h, s = linkage.single_linkage_batch(ea[None], eb[None], w[None], n=n)
    Z_dev = linkage.linkage_to_Z(left[0], right[0], h[0], s[0])
    np.testing.assert_allclose(Z_dev, Z_ref, rtol=1e-6)
