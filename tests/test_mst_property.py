"""Property-based Boruvka checks (hypothesis; skipped if not installed)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import boruvka, ref as oref  # noqa: E402


@st.composite
def random_graphs(draw):
    n = draw(st.integers(4, 60))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # random connected graph: spanning chain + extra edges
    extra = draw(st.integers(0, 4 * n))
    ea = np.concatenate([np.arange(n - 1), rng.integers(0, n, size=extra)])
    eb = np.concatenate([np.arange(1, n), rng.integers(0, n, size=extra)])
    keep = ea != eb
    ea, eb = ea[keep], eb[keep]
    w = rng.choice([0.25, 0.5, 1.0, 2.0, 3.0], size=len(ea)).astype(np.float32)
    # NOTE deliberately FEW distinct weights: stresses tie-breaking
    return n, ea.astype(np.int32), eb.astype(np.int32), w


@given(random_graphs())
@settings(max_examples=40, deadline=None)
def test_boruvka_matches_scipy(g):
    n, ea, eb, w = g
    mask = np.asarray(
        boruvka.boruvka_mst(jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(w), n=n)
    )
    got = np.sort(w[mask])
    want = oref.mst_weights_edge_list(ea, eb, w, n)
    assert mask.sum() == n - 1
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(random_graphs(), st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_boruvka_range_batched(g, reps):
    n, ea, eb, w = g
    w_range = np.stack([w * (1 + 0.1 * i) for i in range(reps)])
    masks = np.asarray(
        boruvka.boruvka_mst_range(
            jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(w_range), n=n
        )
    )
    for i in range(reps):
        want = oref.mst_weights_edge_list(ea, eb, w_range[i], n)
        np.testing.assert_allclose(np.sort(w_range[i][masks[i]]), want, rtol=1e-6)
