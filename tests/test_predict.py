"""Out-of-sample prediction: refit-oracle parity, backend identity, and the
membership/probability surface.

The acceptance bar (ISSUE 4): on blobs/moons/aniso holdouts the predicted
labels match the refit-including-the-point oracle for every fitted mpts —
exact on off-boundary (cluster-core) holdouts, >= 95% overall — and are
identical across the ref / jnp / pallas_interpret backends.
"""

import numpy as np
import pytest

from repro.api import MultiHDBSCAN
from repro.core import predict


KMIN, KMAX = 3, 8


def _blobs(rng, n_per=70):
    return np.concatenate([
        rng.normal((0, 0), 0.3, size=(n_per, 2)),
        rng.normal((4, 0), 0.4, size=(n_per, 2)),
        rng.normal((2, 4), 0.35, size=(n_per, 2)),
    ]).astype(np.float32), np.array([[0, 0], [4, 0], [2, 4]], np.float32)


def _moons(rng, n_per=100):
    t = rng.uniform(0, np.pi, size=n_per)
    upper = np.stack([np.cos(t), np.sin(t)], axis=1)
    t = rng.uniform(0, np.pi, size=n_per)
    lower = np.stack([1.0 - np.cos(t), 0.5 - np.sin(t)], axis=1)
    x = np.concatenate([upper, lower]) + rng.normal(0, 0.06, size=(2 * n_per, 2))
    # arc midpoints: deep inside each moon
    cores = np.array([[np.cos(np.pi / 2), np.sin(np.pi / 2)],
                      [1.0 - np.cos(np.pi / 2), 0.5 - np.sin(np.pi / 2)]])
    return x.astype(np.float32), cores.astype(np.float32)


def _aniso(rng, n_per=70):
    T = np.array([[0.6, -0.6], [-0.4, 0.8]])
    blobs, centers = _blobs(rng, n_per)
    return (blobs @ T).astype(np.float32), (centers @ T).astype(np.float32)


DATASETS = {"blobs": _blobs, "moons": _moons, "aniso": _aniso}


def _match_oracle_label(oracle_train_labels, fitted_labels, oracle_q_label):
    """Map the oracle's label for the query into the fitted labelling by
    majority vote over the (shared) training points."""
    if oracle_q_label < 0:
        return -1
    members = fitted_labels[oracle_train_labels == oracle_q_label]
    members = members[members >= 0]
    if len(members) == 0:
        return -1
    vals, counts = np.unique(members, return_counts=True)
    return int(vals[np.argmax(counts)])


@pytest.mark.parametrize("name", list(DATASETS))
def test_predict_matches_refit_oracle(name):
    """approximate_predict vs refitting WITH the query point, every mpts."""
    rng = np.random.default_rng(17)
    x, cores = DATASETS[name](rng)

    # off-boundary holdouts: jittered cluster cores.  random holdouts: draws
    # from the data distribution (may land on boundaries).
    core_q = np.repeat(cores, 2, axis=0) + rng.normal(0, 0.02, (2 * len(cores), 2))
    rand_q = x[rng.choice(len(x), size=4, replace=False)] + rng.normal(0, 0.05, (4, 2))
    holdout = np.concatenate([core_q, rand_q]).astype(np.float32)
    n_core = len(core_q)

    # a fixed min_cluster_size keeps the planted structure selected at every
    # level (the per-mpts default shatters the moons into fragments whose
    # boundaries run through the arc midpoints — every holdout would be a
    # boundary point, which is not what this test probes)
    opts = dict(kmax=KMAX, kmin=KMIN, min_cluster_size=12)
    est = MultiHDBSCAN(**opts).fit(x)
    res = est.approximate_predict(holdout)
    assert res.labels.shape == (len(est.mpts_values_), len(holdout))

    total = agree = 0
    for qi in range(len(holdout)):
        oracle = MultiHDBSCAN(**opts).fit(
            np.concatenate([x, holdout[qi:qi + 1]])
        )
        for r, mpts in enumerate(est.mpts_values_):
            o_labels = oracle.labels_for(mpts)
            want = _match_oracle_label(o_labels[:-1], est.labels_for(mpts), o_labels[-1])
            got = int(res.labels[r, qi])
            total += 1
            agree += got == want
            if qi < n_core:
                assert got == want, (
                    f"{name}: off-boundary holdout {qi} at mpts={mpts}: "
                    f"predicted {got}, refit oracle says {want}"
                )
    assert agree / total >= 0.95, f"{name}: oracle agreement {agree}/{total}"


def test_predict_identical_across_backends():
    """ref / jnp / pallas_interpret must agree bit-for-bit on predictions
    (shared exact refine pass -> same attachment -> same walk)."""
    import jax

    rng = np.random.default_rng(23)
    x, cores = _blobs(rng)
    q = np.concatenate([
        cores + rng.normal(0, 0.1, cores.shape),
        rng.uniform(-1, 5, size=(5, 2)),
    ]).astype(np.float32)
    backends = ["ref", "jnp"]
    backends.append("pallas" if jax.default_backend() == "tpu" else "pallas_interpret")
    results = {
        b: MultiHDBSCAN(kmax=KMAX, backend=b).fit(x).approximate_predict(q)
        for b in backends
    }
    base = results[backends[0]]
    for b in backends[1:]:
        np.testing.assert_array_equal(base.labels, results[b].labels, err_msg=b)
        np.testing.assert_array_equal(base.neighbors, results[b].neighbors, err_msg=b)
        np.testing.assert_allclose(
            base.probabilities, results[b].probabilities, rtol=1e-6, err_msg=b
        )


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(31)
    x, _ = _blobs(rng)
    return x, MultiHDBSCAN(kmax=KMAX).fit(x)


def test_self_predict_recovers_training_labels(fitted):
    """Feeding the training set back as queries reproduces the fitted
    labelling (boundary ties aside) at every level."""
    x, est = fitted
    res = est.approximate_predict(x)
    for r, mpts in enumerate(est.mpts_values_):
        train = est.labels_for(mpts)
        assert (res.labels[r] == train).mean() >= 0.95, f"mpts={mpts}"


def test_duplicate_of_fitted_point_attaches_with_full_confidence(fitted):
    x, est = fitted
    labels8 = est.labels_for(8)
    i = int(np.flatnonzero(labels8 >= 0)[0])
    lab, prob = est.approximate_predict(x[i:i + 1], mpts=8)
    assert lab[0] == labels8[i]
    assert prob[0] == pytest.approx(1.0)


def test_far_outlier_is_noise_with_zero_probability(fitted):
    x, est = fitted
    res = est.approximate_predict(np.array([[250.0, -250.0]], np.float32))
    assert (res.labels == -1).all()
    assert (res.probabilities == 0.0).all()


def test_single_mpts_and_row_accessor_agree(fitted):
    x, est = fitted
    q = x[:7] + 0.03
    lab, prob = est.approximate_predict(q, mpts=5)
    res = est.approximate_predict(q)
    lab_r, prob_r = res.row(5)
    np.testing.assert_array_equal(lab, lab_r)
    np.testing.assert_allclose(prob, prob_r)


def test_predict_validation_errors(fitted):
    x, est = fitted
    with pytest.raises(RuntimeError, match="not fitted"):
        MultiHDBSCAN(kmax=4).approximate_predict(x[:2])
    with pytest.raises(ValueError, match="2 features"):
        est.approximate_predict(np.zeros((3, 5), np.float32))
    with pytest.raises(KeyError, match="not in computed range"):
        est.approximate_predict(x[:2], mpts=99)
    bad = x[:3].copy()
    bad[1, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite.*row 1"):
        est.approximate_predict(bad)


def test_empty_query_batch_returns_empty_result(fitted):
    x, est = fitted
    res = est.approximate_predict(np.zeros((0, 2), np.float32))
    assert res.labels.shape == (len(est.mpts_values_), 0)
    lab, prob = est.approximate_predict(np.zeros((0, 2), np.float32), mpts=5)
    assert lab.shape == (0,) and prob.shape == (0,)


def test_membership_probabilities_shape_and_bounds(fitted):
    x, est = fitted
    for mpts in (2, 5, 8):
        m = est.membership_for(mpts)
        h = est.hierarchy_for(mpts)
        np.testing.assert_array_equal(m.labels, h.labels)
        assert m.probabilities.shape == (len(x),)
        assert np.all((m.probabilities >= 0.0) & (m.probabilities <= 1.0))
        assert np.all(m.probabilities[m.labels == -1] == 0.0)
        # every cluster core scores full membership
        for c in range(h.n_clusters):
            assert m.probabilities[m.labels == c].max() == pytest.approx(1.0)
        np.testing.assert_array_equal(
            est.probabilities_for(mpts), m.probabilities
        )


def test_walk_table_matches_hierarchy(fitted):
    """The flattened walk table reproduces the labelling it was built from:
    walking each fitted point at (its own neighbor=itself, its departure
    lambda) lands in its own cluster."""
    x, est = fitted
    h = est.hierarchy_for(6)
    table = predict.build_walk_table(h)
    n = len(h.labels)
    labels, probs = predict.walk_queries(
        table, np.arange(n), np.asarray(h.point_lambda)
    )
    np.testing.assert_array_equal(labels, h.labels)
    assert np.all(probs[labels >= 0] > 0.0)
