"""Public estimator API for the multi-density clustering engine.

    from repro.api import MultiHDBSCAN

    est = MultiHDBSCAN(kmax=32).fit(x)
    labels = est.labels_for(mpts=8)        # lazily extracted, cached
    tree = est.hierarchy_for(mpts=8)       # condensed tree + stabilities
    probs = est.probabilities_for(mpts=8)  # per-point membership strength
    profile = est.mpts_profile()           # the whole density range at a glance

    labels, probs = est.approximate_predict(q, mpts=8)   # out-of-sample
    all_levels = est.approximate_predict(q)              # ... every mpts at once
"""

from .estimator import Membership, MultiHDBSCAN

__all__ = ["Membership", "MultiHDBSCAN"]
