"""Execution-plan layer: one resolved `Plan` threaded through every stage.

``Plan`` freezes the execution decisions — kernel backend, optional device
mesh + sharded axis, and every chunk/tile size — ONCE, at the front door
(``repro.api.MultiHDBSCAN`` or ``core.multi.fit_msts``), so the pipeline
stages are pure compositions that never re-derive "where am I running".
``resolve_plan`` mirrors the ``dist.sharding.resolve_rules`` philosophy:
requested placement is filtered against the hardware that actually exists,
so the same user code runs on a laptop (mesh ignored / trivial) and a pod.

``io`` holds the device->host choke point: every bulk materialization in the
pipeline goes through ``to_host``, which a test ledger can count (and, under
``transfer_ledger``, a jax transfer guard turns any *implicit* device->host
sync into an error).
"""

from . import io, plan
from .io import to_host, transfer_ledger
from .plan import Plan, cached_program, resolve_plan

__all__ = [
    "Plan", "cached_program", "io", "plan", "resolve_plan", "to_host",
    "transfer_ledger",
]
