"""Inject the final roofline table into EXPERIMENTS.md (<!-- ROOFLINE_TABLE -->)."""

import re
import sys

sys.path.insert(0, ".")
sys.path.insert(0, "src")

from benchmarks import roofline


def main(art_dir: str = "artifacts/dryrun"):
    recs = roofline.load_records(art_dir)
    table = roofline.render_table(recs, "single")
    with open("EXPERIMENTS.md") as f:
        txt = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in txt:
        txt = txt.replace(marker, table, 1)
    else:
        # replace a previously injected table (first markdown table after §Roofline)
        txt = re.sub(
            r"(Single-pod baseline table.*?\n\n)\|.*?\n\n",
            r"\1" + table + "\n\n",
            txt,
            count=1,
            flags=re.S,
        )
    with open("EXPERIMENTS.md", "w") as f:
        f.write(txt)
    print("injected", len(recs), "records")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
