"""Engine/Plan layer: placement resolution, transfer accounting, backend
parity.  Multi-device cases run in subprocesses (8 fake CPU devices) so the
main pytest process keeps its single-device view."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# plan resolution (single device)
# ---------------------------------------------------------------------------


def test_resolve_plan_single_device():
    from repro import engine

    p = engine.resolve_plan("auto")
    assert not p.sharded and p.n_shards == 1
    assert engine.resolve_plan(p) is p  # already-resolved passthrough
    assert engine.resolve_plan(None).backend == p.backend
    with pytest.raises(ValueError):
        engine.resolve_plan("mesh")  # no usable mesh -> no silent degrade
    with pytest.raises(ValueError):
        engine.resolve_plan("bogus")


def test_trivial_mesh_degrades_to_single():
    """A 1-device mesh (laptop) resolves to the single-device path."""
    from repro import engine
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1,), ("data",))
    p = engine.resolve_plan("auto", mesh=mesh)
    assert not p.sharded
    # axis present but trivial: "mesh" request must refuse, not degrade
    with pytest.raises(ValueError):
        engine.resolve_plan("mesh", mesh=mesh)


def test_estimator_resolves_plan_once(blobs):
    from repro.api import MultiHDBSCAN

    x, _ = blobs
    est = MultiHDBSCAN(kmax=6).fit(x)
    assert est.plan_.describe().startswith("Plan(")
    assert not est.plan_.sharded


# ---------------------------------------------------------------------------
# transfer accounting (single device)
# ---------------------------------------------------------------------------


def test_fit_msts_transfer_ledger(blobs):
    """fit_msts syncs device->host ONLY at the named materialization points,
    with the MST stage contributing exactly one — and the armed jax transfer
    guard proves there are no implicit transfers anywhere in the pipeline."""
    from repro import engine
    from repro.core import multi

    x, _ = blobs
    with engine.transfer_ledger() as led:
        msts = multi.fit_msts(x, 8)
    assert engine.io.tags(led) == [
        "knn", "candidate_count", "stage1_count", "graph", "mst"
    ]
    assert engine.io.count(led, "mst") == 1
    # the sizing syncs are a handful of scalars, not bulk transfers
    assert dict(led)["candidate_count"] <= 32
    assert dict(led)["stage1_count"] <= 16
    assert msts.mst_ea.shape == (7, len(x) - 1)


def test_fit_msts_slot_path_ledger(blobs):
    """The retained slot-array path (ref backend) keeps its own contract:
    two scalar candidate syncs, then graph + mst."""
    from repro import engine
    from repro.core import multi

    x, _ = blobs
    with engine.transfer_ledger() as led:
        multi.fit_msts(x, 8, backend="ref")
    assert engine.io.tags(led) == [
        "knn", "candidate_slots", "candidate_count", "graph", "mst"
    ]
    assert dict(led)["candidate_slots"] <= 8
    assert dict(led)["candidate_count"] <= 8


def test_fit_msts_exact_variant_ledger(blobs):
    from repro import engine
    from repro.core import multi

    x, _ = blobs
    with engine.transfer_ledger() as led:
        multi.fit_msts(x, 6, variant="rng")
    tags = engine.io.tags(led)
    assert tags[0] == "knn" and tags[-1] == "mst"
    assert set(tags) <= {
        "knn", "candidate_slots", "candidate_count", "stage1_count",
        "graph", "lune_exact", "mst"
    }


# ---------------------------------------------------------------------------
# backend parity satellites (single device)
# ---------------------------------------------------------------------------


def test_ref_backend_routes_through_refine():
    """ops.knn(backend="ref") must agree with jnp on near-tie ordering: both
    route their candidates through the same _refine_knn pass."""
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    # lattice + jitter: lots of exactly/nearly tied neighbour distances
    base = np.stack(np.meshgrid(np.arange(12), np.arange(12)), -1).reshape(-1, 2)
    x = (base + rng.normal(0, 1e-4, base.shape)).astype(np.float32)
    import jax.numpy as jnp

    xj = jnp.asarray(x)
    d_r, i_r = ops.knn(xj, 8, backend="ref")
    d_j, i_j = ops.knn(xj, 8, backend="jnp")
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_j), rtol=1e-6, atol=1e-7)
    assert (np.asarray(i_r) == np.asarray(i_j)).all()


def test_sbcn_large_row_chunking_matches_unchunked():
    """The oversized-pair path must give identical verdicts regardless of
    row_chunk (bounded peak memory, same SBCN mask)."""
    import jax.numpy as jnp

    from repro.core import sbcn

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(700, 3)).astype(np.float32))
    cd2k = jnp.asarray(rng.uniform(0.1, 0.5, size=700).astype(np.float32))
    a = jnp.asarray(rng.permutation(700)[:600].astype(np.int32))
    b = jnp.asarray(rng.permutation(700)[:90].astype(np.int32))
    full = np.asarray(sbcn._sbcn_large(x, cd2k, a, b, row_chunk=1024))
    chunked = np.asarray(sbcn._sbcn_large(x, cd2k, a, b, row_chunk=64))
    assert full.shape == (600, 90)
    assert (full == chunked).all()


def test_sbcn_edges_wrapper_matches_candidates(blobs):
    """sbcn_edges (host view) == compacted sbcn_candidates (device view)."""
    import jax.numpy as jnp

    from repro.core import mrd, sbcn, wspd
    from repro.kernels import ops

    x, _ = blobs
    xj = jnp.asarray(x)
    knn_d2, _ = ops.knn(xj, 7)
    cd2 = mrd.core_distances2(knn_d2)
    cdk = np.sqrt(np.asarray(cd2[:, -1], np.float64))
    tree = wspd.build_fair_split_tree(np.asarray(x, np.float64), cdk)
    pu, pv = wspd.wspd_pairs(tree)
    args = (
        tree.perm,
        tree.start[pu], tree.end[pu] - tree.start[pu],
        tree.start[pv], tree.end[pv] - tree.start[pv],
    )
    edges = sbcn.sbcn_edges(xj, cd2[:, -1], *args)
    lo, hi, keep = sbcn.sbcn_candidates(xj, cd2[:, -1], *args)
    lo, hi, keep = np.asarray(lo), np.asarray(hi), np.asarray(keep)
    np.testing.assert_array_equal(edges[:, 0], lo[keep])
    np.testing.assert_array_equal(edges[:, 1], hi[keep])
    # uniqueness + canonical a < b ordering preserved
    assert (edges[:, 0] < edges[:, 1]).all()
    packed = edges[:, 0] * len(x) + edges[:, 1]
    assert len(np.unique(packed)) == len(packed)


# ---------------------------------------------------------------------------
# mesh backends + sharded pipeline parity (8 fake devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_mesh_knn_backend_matches_local():
    """kernels.ops.knn(backend='mesh') == backend='jnp', including the shared
    refine pass, with n NOT divisible by the axis size."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.kernels import ops
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(261, 5)).astype(np.float32))  # 261 % 8 != 0
    d_m, i_m = ops.knn(x, 7, backend="mesh", mesh=mesh)
    d_j, i_j = ops.knn(x, 7, backend="jnp")
    np.testing.assert_allclose(np.asarray(d_m), np.asarray(d_j), rtol=1e-6, atol=1e-7)
    assert (np.asarray(i_m) == np.asarray(i_j)).all()
    """)


@pytest.mark.slow
def test_mesh_lune_backend_matches_local():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.kernels import ops
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(237, 4)).astype(np.float32))  # 237 % 8 != 0
    d2, _ = ops.knn(x, 6, backend="jnp")
    cd2 = d2[:, 4]
    ea = jnp.asarray(rng.integers(0, 237, size=96).astype(np.int32))
    eb = jnp.asarray(rng.integers(0, 237, size=96).astype(np.int32))
    d2ab = jnp.sum((x[ea]-x[eb])**2, -1)
    w2 = jnp.maximum(jnp.maximum(cd2[ea], cd2[eb]), d2ab)
    got = np.asarray(ops.lune_nonempty(ea, eb, w2, x, cd2, backend="mesh", mesh=mesh))
    want = np.asarray(ops.lune_nonempty(ea, eb, w2, x, cd2, backend="jnp"))
    assert (got == want).all()
    """)


@pytest.mark.slow
def test_sharded_boruvka_range_matches_local():
    _run("""
    import numpy as np, jax.numpy as jnp
    from repro.core import boruvka
    from repro.dist.cluster_parallel import sharded_mst_range
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((8,), ("data",))
    rng = np.random.default_rng(2)
    n, m, R = 120, 600, 11                      # R % 8 != 0: row padding path
    ea = rng.integers(0, n, size=m).astype(np.int32)
    eb = (ea + 1 + rng.integers(0, n - 1, size=m).astype(np.int32)) % n
    ea_j, eb_j = jnp.asarray(ea), jnp.asarray(eb)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(R, m)).astype(np.float32))
    # ensure connectivity: add a path
    ea_j = jnp.concatenate([ea_j, jnp.arange(n - 1, dtype=jnp.int32)])
    eb_j = jnp.concatenate([eb_j, jnp.arange(1, n, dtype=jnp.int32)])
    w = jnp.concatenate([w, jnp.full((R, n - 1), 3.0, jnp.float32)], axis=1)
    got = np.asarray(sharded_mst_range(ea_j, eb_j, w, n=n, mesh=mesh))
    want = np.asarray(boruvka.boruvka_mst_range(ea_j, eb_j, w, n=n))
    assert (got == want).all()
    """)


@pytest.mark.slow
def test_sharded_pipeline_matches_single_device():
    """Acceptance: on an 8-virtual-device CPU mesh, MultiHDBSCAN(mesh=...)
    produces labels identical to the single-device path for all mpts in
    [2, 16] on blob/moons fixtures, with matching MST weight multisets, and
    the MST stage performs exactly one device->host transfer (ledgered, with
    the jax transfer guard rejecting implicit syncs)."""
    _run("""
    import numpy as np
    from repro import engine
    from repro.api import MultiHDBSCAN
    from repro.core import multi
    from repro.launch.mesh import make_mesh_compat

    rng = np.random.default_rng(0)
    blobs = np.concatenate([
        rng.normal((0, 0), 0.3, size=(100, 2)),
        rng.normal((4, 0), 0.5, size=(100, 2)),
        rng.normal((2, 4), 0.6, size=(77, 2)),    # n=277: padding path
    ]).astype(np.float32)
    t = rng.uniform(0, np.pi, size=(120,))
    moons = np.concatenate([
        np.stack([np.cos(t), np.sin(t)], 1),
        np.stack([1.0 - np.cos(t), 0.5 - np.sin(t)], 1),
    ]).astype(np.float32) + rng.normal(0, 0.06, size=(240, 2)).astype(np.float32)

    mesh = make_mesh_compat((8,), ("data",))
    for x in (blobs, moons):
        single = MultiHDBSCAN(kmax=16).fit(x)
        with engine.transfer_ledger() as led:
            msts = multi.fit_msts(x, 16, plan=engine.resolve_plan("mesh", mesh=mesh))
        assert engine.io.count(led, "mst") == 1, engine.io.tags(led)
        sharded = MultiHDBSCAN(kmax=16, mesh=mesh, plan="mesh").fit(x)
        assert sharded.plan_.sharded and sharded.plan_.n_shards == 8
        for mpts in range(2, 17):
            _, _, w1 = single.mst_for(mpts)
            _, _, w2 = sharded.mst_for(mpts)
            np.testing.assert_allclose(np.sort(w1), np.sort(w2), rtol=1e-5, atol=1e-6)
            l1, l2 = single.labels_for(mpts), sharded.labels_for(mpts)
            assert (l1 == l2).all(), (mpts, int((l1 != l2).sum()))
    print("sharded parity ok")
    """)
