"""qwen2.5-14b [dense] — 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

QKV bias, SwiGLU, head_dim 128.  [hf:Qwen/Qwen2.5-0.5B; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    arch="transformer",
    vocab=152064,
    d_model=5120,
    n_layers=48,
    n_heads=40,
    n_kv=8,
    d_head=128,
    d_ff=13824,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    microbatch=2,
    run_long_500k=False,
    skip_note="pure full attention; long_500k skipped per task rule",
)
