"""End-to-end driver: "over one hundred hierarchies for the cost of two".

``multi_hdbscan``  — the paper's method: one (kmax-1)-NN pass, one RNG^kmax,
then per-mpts {reweight -> MST -> hierarchy} with the MST range batched into
a single device program.

``hdbscan_baseline`` — the paper's *optimized* comparison baseline: the same
single kNN pass (core distances shared across the range), then an O(n^2)
complete-graph MST per mpts (dense Prim, nothing materialized).

Both return per-mpts hierarchies/labels through the same host-side extraction
(core.hierarchy), so benchmark ratios isolate exactly the graph/MST work the
paper optimizes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import boruvka, hierarchy
from . import mrd as mrd_mod
from .rng import RngGraph, build_rng_graph


@dataclasses.dataclass
class HierarchyResult:
    mpts: int
    labels: np.ndarray
    n_clusters: int
    condensed: hierarchy.CondensedTree
    stability: dict[int, float]
    mst_ea: np.ndarray
    mst_eb: np.ndarray
    mst_w: np.ndarray  # real (non-squared) mrd weights


@dataclasses.dataclass
class MultiDensityResult:
    n: int
    kmax: int
    mpts_values: list[int]
    graph: RngGraph
    knn_d2: np.ndarray
    knn_idx: np.ndarray
    cd2: np.ndarray
    hierarchies: list[HierarchyResult]
    timings: dict[str, float]


def _extract_one(
    mpts: int,
    ea: np.ndarray,
    eb: np.ndarray,
    w: np.ndarray,
    n: int,
    min_cluster_size: int | None,
    allow_single_cluster: bool,
) -> HierarchyResult:
    mcs = min_cluster_size if min_cluster_size is not None else max(2, mpts)
    labels, tree, stab = hierarchy.hdbscan_labels(
        ea, eb, w, n, mcs, allow_single_cluster=allow_single_cluster
    )
    return HierarchyResult(
        mpts=mpts,
        labels=labels,
        n_clusters=int(labels.max()) + 1,
        condensed=tree,
        stability=stab,
        mst_ea=ea,
        mst_eb=eb,
        mst_w=w,
    )


def multi_hdbscan(
    x,
    kmax: int,
    *,
    kmin: int = 2,
    variant: str = "rng_star",
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    backend: str | None = None,
    compute_hierarchies: bool = True,
    mpts_values: Sequence[int] | None = None,
) -> MultiDensityResult:
    """All HDBSCAN* hierarchies for mpts in [kmin, kmax] via one RNG^kmax."""
    x = jnp.asarray(x)
    n = x.shape[0]
    if kmax < 2 or kmax > n:
        raise ValueError(f"kmax must be in [2, n]; got {kmax} (n={n})")
    mpts_list = list(mpts_values) if mpts_values is not None else list(range(kmin, kmax + 1))
    timings: dict[str, float] = {}

    t0 = time.monotonic()
    knn_d2, knn_idx = kernels.ops.knn(x, kmax - 1, backend=backend)
    knn_d2.block_until_ready()
    timings["knn"] = time.monotonic() - t0

    t0 = time.monotonic()
    graph = build_rng_graph(x, knn_d2, knn_idx, variant=variant, backend=backend)
    timings["rng_build"] = time.monotonic() - t0

    cd2 = np.asarray(mrd_mod.core_distances2(knn_d2))
    ea = jnp.asarray(graph.edges[:, 0], jnp.int32)
    eb = jnp.asarray(graph.edges[:, 1], jnp.int32)

    t0 = time.monotonic()
    cd2_dev = jnp.asarray(cd2)
    w_range = mrd_mod.reweight_all_mpts(jnp.asarray(graph.d2), cd2_dev, ea, eb)
    w_sel = w_range[jnp.asarray([m - 1 for m in mpts_list])]
    in_mst = boruvka.boruvka_mst_range(ea, eb, w_sel, n=n)
    in_mst.block_until_ready()
    timings["mst_range"] = time.monotonic() - t0

    hierarchies: list[HierarchyResult] = []
    t0 = time.monotonic()
    in_mst_np = np.asarray(in_mst)
    w_sel_np = np.asarray(w_sel)
    if compute_hierarchies:
        for row, mpts in enumerate(mpts_list):
            sel = in_mst_np[row]
            hierarchies.append(
                _extract_one(
                    mpts,
                    graph.edges[sel, 0],
                    graph.edges[sel, 1],
                    np.sqrt(w_sel_np[row][sel]),
                    n,
                    min_cluster_size,
                    allow_single_cluster,
                )
            )
    timings["hierarchy"] = time.monotonic() - t0
    timings["total"] = sum(timings.values())

    return MultiDensityResult(
        n=n,
        kmax=kmax,
        mpts_values=mpts_list,
        graph=graph,
        knn_d2=np.asarray(knn_d2),
        knn_idx=np.asarray(knn_idx),
        cd2=cd2,
        hierarchies=hierarchies,
        timings=timings,
    )


def hdbscan_baseline(
    x,
    mpts_values: Sequence[int],
    *,
    kmax: int | None = None,
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    backend: str | None = None,
    compute_hierarchies: bool = True,
) -> tuple[list[HierarchyResult], dict[str, float]]:
    """Paper's baseline: shared kNN pass + dense complete-graph MST per mpts."""
    x = jnp.asarray(x)
    n = x.shape[0]
    kmax = kmax or max(mpts_values)
    timings: dict[str, float] = {}

    t0 = time.monotonic()
    knn_d2, _ = kernels.ops.knn(x, kmax - 1, backend=backend)
    cd2 = mrd_mod.core_distances2(knn_d2)
    cd2.block_until_ready()
    timings["knn"] = time.monotonic() - t0

    results = []
    t_mst = 0.0
    t_h = 0.0
    for mpts in mpts_values:
        t0 = time.monotonic()
        src, w2 = boruvka.prim_dense_mst(x, cd2[:, mpts - 1])
        w2.block_until_ready()
        t_mst += time.monotonic() - t0
        t0 = time.monotonic()
        if compute_hierarchies:
            v = np.arange(1, n)
            results.append(
                _extract_one(
                    mpts,
                    np.asarray(src)[1:],
                    v,
                    np.sqrt(np.asarray(w2)[1:]),
                    n,
                    min_cluster_size,
                    allow_single_cluster,
                )
            )
        t_h += time.monotonic() - t0
    timings["mst"] = t_mst
    timings["hierarchy"] = t_h
    timings["total"] = timings["knn"] + t_mst + t_h
    return results, timings
