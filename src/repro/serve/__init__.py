"""Serving layer.

``engine.ClusterServeEngine`` is the clustering serve surface (the repo's
actual workload): fit-once process-resident state, micro-batched
out-of-sample prediction, LRU-bounded per-mpts extraction.  ``lm`` keeps
the small batched LM decode engine used by the accelerator-side smoke
tests and examples/serve_lm.py.
"""

from . import engine, lm
from .engine import ClusterServeEngine

__all__ = ["ClusterServeEngine", "engine", "lm"]
