"""kimi-k2-1t-a32b [moe] — 61L d=7168 64H (GQA kv=8) vocab=163840.

MoE 384 routed top-8 + 1 shared, expert d_ff=2048; ~1.04T total params,
~32B active.  The assignment specifies GQA kv=8 (real K2 uses MLA; the
assigned table wins — DESIGN.md §5).  Trains on 512 v5e only with bf16
master + int8 blockwise Adam + microbatch=1 (DESIGN.md §8).
[arXiv:2501.kimi2; paper-table]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    arch="transformer",
    vocab=163840,
    d_model=7168,
    n_layers=61,
    n_heads=64,
    n_kv=8,
    d_head=128,
    d_ff=0,
    act="swiglu",
    n_experts=384,
    n_shared=1,
    top_k=8,
    d_ff_expert=2048,
    rope_theta=50_000.0,
    tie_embeddings=False,
    microbatch=8,
    param_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    optimizer_state_dtype="int8",
    run_long_500k=False,
    skip_note="pure full attention; long_500k skipped per task rule",
)
