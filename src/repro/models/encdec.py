"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder consumes STUB audio-frame embeddings (B, S_enc, frontend_dim) — the
modality frontend is out of scope per the task; the decoder is a causal text
stack with cross-attention.  Both stacks scan over layers.

Serving: encoder output K/V per decoder layer are precomputed at prefill and
stay static during decode; the decoder self-attention cache grows as usual.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers as L


def _attn_init(key, cfg):
    kk = jax.random.split(key, 4)
    d = cfg.d_model
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv * cfg.d_head
    p, s = {}, {}
    p["wq"], s["wq"] = L.dense_init(kk[0], (d, hq), ("embed", "heads_dim"), jnp.float32)
    p["wk"], s["wk"] = L.dense_init(kk[1], (d, hkv), ("embed", "kv_dim"), jnp.float32)
    p["wv"], s["wv"] = L.dense_init(kk[2], (d, hkv), ("embed", "kv_dim"), jnp.float32)
    p["wo"], s["wo"] = L.dense_init(kk[3], (hq, d), ("heads_dim", "embed"), jnp.float32)
    return p, s


def init(cfg, key):
    ks = iter(jax.random.split(key, 16))
    d = cfg.d_model
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
    )
    p["unembed"], s["unembed"] = L.dense_init(
        next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
    )
    p["proj_in"], s["proj_in"] = L.dense_init(
        next(ks), (cfg.frontend_dim, d), ("frontend", "embed"), jnp.float32
    )
    p["enc_norm"], s["enc_norm"] = L.rmsnorm_init(d)
    p["dec_norm"], s["dec_norm"] = L.rmsnorm_init(d)

    def stack(initfn, count, base_key, extra=()):
        outs = [initfn(jax.random.fold_in(base_key, i)) for i in range(count)]
        params = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        specs = jax.tree.map(
            lambda sp: ("layers",) + sp,
            outs[0][1],
            is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, str) for e in v),
        )
        return params, specs

    def enc_layer(k):
        kk = jax.random.split(k, 2)
        lp, ls = {}, {}
        lp["ln1"], ls["ln1"] = L.rmsnorm_init(d)
        lp["attn"], ls["attn"] = _attn_init(kk[0], cfg)
        lp["ln2"], ls["ln2"] = L.rmsnorm_init(d)
        lp["mlp"], ls["mlp"] = L.init_mlp(kk[1], cfg, cfg.d_ff)
        return lp, ls

    def dec_layer(k):
        kk = jax.random.split(k, 3)
        lp, ls = {}, {}
        lp["ln1"], ls["ln1"] = L.rmsnorm_init(d)
        lp["self_attn"], ls["self_attn"] = _attn_init(kk[0], cfg)
        lp["ln_x"], ls["ln_x"] = L.rmsnorm_init(d)
        lp["cross_attn"], ls["cross_attn"] = _attn_init(kk[1], cfg)
        lp["ln2"], ls["ln2"] = L.rmsnorm_init(d)
        lp["mlp"], ls["mlp"] = L.init_mlp(kk[2], cfg, cfg.d_ff)
        return lp, ls

    p["enc"], s["enc"] = stack(enc_layer, cfg.n_enc_layers, next(ks))
    p["dec"], s["dec"] = stack(dec_layer, cfg.n_dec_layers, next(ks))
    return p, s


def _attn(pl, hq_in, hkv_in, cfg, q_pos, k_pos, causal, kv_valid=None, cache_kv=None,
          use_rope=True):
    b, sq, d = hq_in.shape
    dt = hq_in.dtype
    q = (hq_in @ pl["wq"].astype(dt)).reshape(b, sq, cfg.n_heads, cfg.d_head)
    if cache_kv is None:
        sk = hkv_in.shape[1]
        k = (hkv_in @ pl["wk"].astype(dt)).reshape(b, sk, cfg.n_kv, cfg.d_head)
        v = (hkv_in @ pl["wv"].astype(dt)).reshape(b, sk, cfg.n_kv, cfg.d_head)
        if use_rope:
            k = L.rope(k, k_pos[None, :], cfg.rope_theta)
    else:
        k, v = cache_kv
    if use_rope:
        q = L.rope(q, q_pos[None, :], cfg.rope_theta)
    if causal:
        o = L.attention(q, k, v, q_pos=q_pos, k_pos=k_pos, window=0, kv_valid=kv_valid)
    else:
        # bidirectional: run with positions shifted so the causal mask never
        # bites (q_pos = max) while rope used real positions above
        o = L.attention(
            q, k, v,
            q_pos=jnp.full_like(q_pos, 2**29), k_pos=jnp.zeros_like(k_pos),
            window=0, kv_valid=kv_valid,
        )
    return o.reshape(b, sq, -1) @ pl["wo"].astype(dt), (k, v)


def encode(p, cfg, frames):
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) @ p["proj_in"].astype(dt)
    s_enc = x.shape[1]
    pos = jnp.arange(s_enc, dtype=jnp.int32)

    def body(x, pl):
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = L.rmsnorm(x, pl["ln1"])
        o, _ = _attn(pl["attn"], h, h, cfg, pos, pos, causal=False)
        x = x + o
        h2 = L.rmsnorm(x, pl["ln2"])
        x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p["enc"])
    return L.rmsnorm(x, p["enc_norm"])


def forward(p, cfg, dec_tokens, frames):
    """Training forward: returns decoder hidden states (B, S_dec, D), aux=0."""
    enc_out = encode(p, cfg, frames)
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[dec_tokens]
    s_dec = dec_tokens.shape[1]
    s_enc = enc_out.shape[1]
    dpos = jnp.arange(s_dec, dtype=jnp.int32)
    epos = jnp.arange(s_enc, dtype=jnp.int32)

    def body(x, pl):
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = L.rmsnorm(x, pl["ln1"])
        o, _ = _attn(pl["self_attn"], h, h, cfg, dpos, dpos, causal=True)
        x = x + o
        hx = L.rmsnorm(x, pl["ln_x"])
        o, _ = _attn(pl["cross_attn"], hx, enc_out, cfg, dpos, epos,
                     causal=False, use_rope=False)
        x = x + o
        h2 = L.rmsnorm(x, pl["ln2"])
        x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p["dec"])
    return L.rmsnorm(x, p["dec_norm"]), jnp.float32(0.0)


def logits_fn(p, cfg, x):
    return x @ p["unembed"].astype(x.dtype).T


def init_cache(cfg, batch: int, max_len: int, enc_len: int | None = None,
               dtype=jnp.bfloat16):
    enc_len = enc_len or max_len
    dec_len = max(1, int(max_len * cfg.dec_seq_frac))
    return {
        "k": jnp.zeros((cfg.n_dec_layers, batch, dec_len, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((cfg.n_dec_layers, batch, dec_len, cfg.n_kv, cfg.d_head), dtype),
        "xk": jnp.zeros((cfg.n_dec_layers, batch, enc_len, cfg.n_kv, cfg.d_head), dtype),
        "xv": jnp.zeros((cfg.n_dec_layers, batch, enc_len, cfg.n_kv, cfg.d_head), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(p, cfg, frames, max_len: int, cache_dtype=jnp.bfloat16):
    """Encode + precompute per-dec-layer cross K/V; empty self cache."""
    enc_out = encode(p, cfg, frames)
    b, s_enc, _ = enc_out.shape
    dt = enc_out.dtype
    epos = jnp.arange(s_enc, dtype=jnp.int32)

    def body(_, pl):
        k = (enc_out @ pl["cross_attn"]["wk"].astype(dt)).reshape(
            b, s_enc, cfg.n_kv, cfg.d_head)
        v = (enc_out @ pl["cross_attn"]["wv"].astype(dt)).reshape(
            b, s_enc, cfg.n_kv, cfg.d_head)
        return None, (k.astype(cache_dtype), v.astype(cache_dtype))

    _, (xk, xv) = jax.lax.scan(body, None, p["dec"])
    cache = init_cache(cfg, b, max_len, enc_len=s_enc, dtype=cache_dtype)
    cache = dict(cache, xk=xk, xv=xv)
    bos = jnp.zeros((b, 1), jnp.int32)
    logits, cache = decode_step(p, cfg, cache, bos)
    return logits, cache


def decode_step(p, cfg, cache, cur_tokens):
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = p["embed"].astype(dt)[cur_tokens]
    dec_len = cache["k"].shape[2]
    s_enc = cache["xk"].shape[2]
    positions = pos[None].astype(jnp.int32)
    k_pos = jnp.arange(dec_len, dtype=jnp.int32)
    epos = jnp.arange(s_enc, dtype=jnp.int32)
    kv_valid = k_pos <= pos

    def body(carry, pl):
        x, cache, li = carry
        h = L.rmsnorm(x, pl["ln1"])
        _, (k_new, v_new) = _attn(pl["self_attn"], h, h, cfg, positions, positions, True)
        k_all = jax.lax.dynamic_update_slice(
            cache["k"][li], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v"][li], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        cache = dict(
            cache,
            k=jax.lax.dynamic_update_index_in_dim(cache["k"], k_all, li, 0),
            v=jax.lax.dynamic_update_index_in_dim(cache["v"], v_all, li, 0),
        )
        o, _ = _attn(pl["self_attn"], h, None, cfg, positions, k_pos, True,
                     kv_valid, (k_all.astype(dt), v_all.astype(dt)))
        x = x + o
        hx = L.rmsnorm(x, pl["ln_x"])
        o, _ = _attn(pl["cross_attn"], hx, None, cfg, positions, epos, False,
                     None, (cache["xk"][li].astype(dt), cache["xv"][li].astype(dt)),
                     use_rope=False)
        x = x + o
        h2 = L.rmsnorm(x, pl["ln2"])
        x = x + L.mlp(pl["mlp"], h2, cfg, cfg.d_ff)
        return (x, cache, li + 1), None

    (x, cache, _), _ = jax.lax.scan(body, (x, cache, jnp.int32(0)), p["dec"])
    x = L.rmsnorm(x, p["dec_norm"])
    logits = logits_fn(p, cfg, x)
    return logits[:, 0], dict(cache, pos=pos + 1)
