"""Property-based checks of the dual-tree traversal machinery (hypothesis;
skipped if not installed).

These pin the three facts the oracle-parity harness relies on:

  * node-pair distance bounds are SOUND — lb2 <= true min pairwise d2 <= ub2
    for every node pair of the fair-split tree, at any leaf size;
  * the kNN traversal's pruning never drops a true neighbour — its candidate
    output contains the exact f64 top-k distance multiset per point, so a
    pruned node pair provably held no candidate-improving point;
  * the Borůvka candidate graph spans and supports a full-weight MST — the
    exact MST over ``candidate_edges`` output equals the exact MST over the
    complete mrd_kmax graph (f64 Prim), including on duplicate-heavy and
    collinear inputs where mutual-reachability ties are pervasive.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dualtree  # noqa: E402


@st.composite
def point_sets(draw):
    """Point clouds biased toward degeneracy: quantized coordinates create
    duplicates; d=1 embedded in d>=1 gives collinear runs."""
    n = draw(st.integers(10, 72))
    d = draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=draw(st.floats(0.5, 8.0)), size=(n, d))
    mode = draw(st.integers(0, 2))
    if mode == 1:       # duplicate-heavy: snap to a coarse grid
        x = np.round(x * 2) / 2
    elif mode == 2:     # collinear: one informative axis
        x[:, 1:] = 0.0
    return np.ascontiguousarray(x)


def _brute_knn_d2(x: np.ndarray, k: int) -> np.ndarray:
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    return np.sort(d2, axis=1)[:, :k]


def _mrd2(x: np.ndarray, cd2: np.ndarray) -> np.ndarray:
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    return np.maximum(d2, np.maximum(cd2[:, None], cd2[None, :]))


def _prim_mst_weight(w: np.ndarray) -> float:
    """Total MST weight of a dense symmetric weight matrix (exact, f64)."""
    n = len(w)
    in_tree = np.zeros(n, bool)
    best = np.full(n, np.inf)
    in_tree[0] = True
    best = np.minimum(best, w[0])
    best[0] = np.inf
    total = 0.0
    for _ in range(n - 1):
        j = int(np.argmin(best))
        total += best[j]
        in_tree[j] = True
        best = np.where(in_tree, np.inf, np.minimum(best, w[j]))
    return total


@given(point_sets(), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_node_pair_bounds_sound(x, leaf_size):
    ix = dualtree.build_index(x, np.zeros(len(x)), leaf_size=leaf_size)
    tree = ix.tree
    n_nodes = tree.n_nodes
    U, V = np.meshgrid(np.arange(n_nodes), np.arange(n_nodes), indexing="ij")
    U, V = U.ravel(), V.ravel()
    ns = U != V
    U, V = U[ns], V[ns]
    lb2 = dualtree.node_pair_lb2(ix, U, V)
    ub2 = dualtree.node_pair_ub2(ix, U, V)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    for u, v, lo, hi in zip(U, V, lb2, ub2):
        pu = tree.perm[tree.start[u]:tree.end[u]]
        pv = tree.perm[tree.start[v]:tree.end[v]]
        true_min = d2[np.ix_(pu, pv)].min()
        assert lo <= true_min * (1 + 1e-12) + 1e-12
        assert true_min <= hi * (1 + 1e-12) + 1e-12


@given(point_sets(), st.integers(1, 8), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_knn_traversal_never_drops_a_neighbour(x, k, leaf_size):
    """The candidate rows contain the exact top-k: the pruned node pairs held
    no improving point.  (Compared as distance multisets — at a tied kth
    boundary any tied member is an equally correct candidate.)"""
    k = min(k, len(x) - 1)
    cand = dualtree.knn_candidates(x, k, leaf_size=leaf_size)
    assert cand.shape == (len(x), k)
    assert (cand >= 0).all()
    ref = _brute_knn_d2(x, k)
    for i, row in enumerate(cand):
        got = np.sort(((x[row] - x[i]) ** 2).sum(-1))
        np.testing.assert_allclose(got, ref[i], rtol=1e-12, atol=1e-12)


@given(point_sets(), st.integers(2, 8), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_boruvka_candidates_support_exact_mst(x, kmax, leaf_size):
    kmax = min(kmax, len(x) - 1)
    k = kmax - 1
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    knn_d2 = np.sort(d2, axis=1)[:, :k]
    knn_idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    cd2 = knn_d2[:, -1]

    edges, stats = dualtree.candidate_edges(
        x, knn_d2, knn_idx, leaf_size=leaf_size
    )
    assert stats["m_candidates"] == len(edges)
    assert (edges[:, 0] < edges[:, 1]).all()

    w = _mrd2(x, cd2)
    np.fill_diagonal(w, np.inf)
    # exact MST over the candidate graph == exact MST over the complete graph
    w_cand = np.full_like(w, np.inf)
    w_cand[edges[:, 0], edges[:, 1]] = w[edges[:, 0], edges[:, 1]]
    w_cand[edges[:, 1], edges[:, 0]] = w[edges[:, 1], edges[:, 0]]
    total_cand = _prim_mst_weight(w_cand)
    total_full = _prim_mst_weight(w)
    assert np.isfinite(total_cand)  # candidate graph spans
    np.testing.assert_allclose(total_cand, total_full, rtol=1e-12)


@given(point_sets())
@settings(max_examples=15, deadline=None)
def test_node_agg_matches_bruteforce(x):
    ix = dualtree.build_index(x, np.zeros(len(x)), leaf_size=3)
    tree = ix.tree
    rng = np.random.default_rng(0)
    vals = rng.normal(size=len(x))
    agg_min = dualtree.node_agg(ix, vals, np.minimum)
    agg_max = dualtree.node_agg(ix, vals, np.maximum)
    for node in range(tree.n_nodes):
        pts = tree.perm[tree.start[node]:tree.end[node]]
        assert agg_min[node] == vals[pts].min()
        assert agg_max[node] == vals[pts].max()
