"""End-to-end driver: "over one hundred hierarchies for the cost of two".

Staged pipeline (each stage reusable on its own; ``repro.api.MultiHDBSCAN``
is the front door that composes them lazily):

  ``fit_msts``          — one (kmax-1)-NN pass, one RNG^kmax, reweight for the
                          whole mpts range, batched Borůvka: all R MSTs as
                          (R, n-1) edge arrays.  Device-heavy, done once.
  ``linkage_range``     — stage 1 of extraction: all R single-linkage
                          dendrograms in ONE vmapped device program
                          (core.linkage), no per-edge Python loop.
  ``extract_hierarchies`` / ``extract_one_from_linkage``
                        — stage 2: vectorized condense/stability/labels
                          (core.hierarchy fast path) per requested mpts, so
                          hierarchies materialize on demand.

``multi_hdbscan``  — the paper's method end-to-end (eager extraction of the
whole range), kept as the one-call entry point for scripts and tests.

``hdbscan_baseline`` — the paper's *optimized* comparison baseline: the same
single kNN pass (core distances shared across the range), then an O(n^2)
complete-graph MST per mpts (dense Prim, nothing materialized).

Both return per-mpts hierarchies/labels through the same batched extraction,
so benchmark ratios isolate exactly the graph/MST work the paper optimizes.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import engine
from . import hierarchy, linkage
from . import boruvka
from . import mrd as mrd_mod
from .rng import RngGraph, build_rng_graph


@dataclasses.dataclass
class HierarchyResult:
    mpts: int
    labels: np.ndarray
    n_clusters: int
    condensed: hierarchy.CondensedTree
    stability: dict[int, float]  # every condensed cluster, selected or not
    mst_ea: np.ndarray
    mst_eb: np.ndarray
    mst_w: np.ndarray  # real (non-squared) mrd weights
    selected: list[int] = dataclasses.field(default_factory=list)  # chosen cluster ids
    point_lambda: np.ndarray | None = None  # (n,) departure lambda (0 for noise)


def _validate_min_cluster_size(min_cluster_size: int | None) -> None:
    if min_cluster_size is not None and min_cluster_size < 2:
        raise ValueError(
            f"min_cluster_size must be >= 2 (or None for the per-mpts "
            f"default max(2, mpts)); got {min_cluster_size}"
        )


@dataclasses.dataclass
class MultiMSTResult:
    """Everything shared across the mpts range, before any extraction."""

    n: int
    kmax: int
    mpts_values: list[int]
    graph: RngGraph | None
    knn_d2: np.ndarray
    knn_idx: np.ndarray
    cd2: np.ndarray
    mst_ea: np.ndarray  # (R, n-1) int32: MST edges per mpts row
    mst_eb: np.ndarray  # (R, n-1) int32
    mst_w: np.ndarray   # (R, n-1) float32, real (non-squared) mrd weights
    timings: dict[str, float]

    def row_of(self, mpts: int) -> int:
        try:
            return self.mpts_values.index(mpts)
        except ValueError:
            raise KeyError(
                f"mpts={mpts} not in computed range {self.mpts_values}"
            ) from None


@dataclasses.dataclass
class LinkageRange:
    """Stage-1 extraction output: all R dendrograms, scipy convention."""

    left: np.ndarray    # (R, n-1) int32
    right: np.ndarray   # (R, n-1) int32
    height: np.ndarray  # (R, n-1) float32, ascending per row
    size: np.ndarray    # (R, n-1) int32


@dataclasses.dataclass
class MultiDensityResult:
    n: int
    kmax: int
    mpts_values: list[int]
    graph: RngGraph
    knn_d2: np.ndarray
    knn_idx: np.ndarray
    cd2: np.ndarray
    hierarchies: list[HierarchyResult]
    timings: dict[str, float]


@functools.partial(jax.jit, static_argnames=("n",))
def _mst_stage_local(d2_pad, cd2_dev, ea, eb, row_idx, *, n: int):
    """Single-device MST stage as ONE program: reweight + batched Borůvka +
    row compaction, no intermediate materialization between steps."""
    w_range = mrd_mod.reweight_all_mpts(d2_pad, cd2_dev, ea, eb)
    w_sel = w_range[row_idx]
    in_mst = boruvka.boruvka_mst_range(ea, eb, w_sel, n=n)
    return _compact_mst_rows(in_mst, ea, eb, w_sel, n=n)


@functools.partial(jax.jit, static_argnames=("n",))
def _compact_mst_rows(in_mst, ea, eb, w_sel, *, n: int):
    """(R, m) MST mask -> (R, n-1) ascending edge-id compaction + counts."""
    R, m = in_mst.shape
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    dst = jnp.where(in_mst, jnp.cumsum(in_mst, axis=1) - 1, n - 1)
    sel = (
        jnp.zeros((R, n - 1), jnp.int32)
        .at[rows, dst]
        .set(jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (R, m)), mode="drop")
    )
    counts = jnp.sum(in_mst, axis=1)
    mst_w = jnp.sqrt(jnp.take_along_axis(w_sel, sel, axis=1))
    return ea[sel], eb[sel], mst_w, counts


def fit_msts(
    x,
    kmax: int,
    *,
    kmin: int = 2,
    variant: str = "rng_star",
    backend: str | None = None,
    mpts_values: Sequence[int] | None = None,
    plan: "engine.Plan | str | None" = None,
) -> MultiMSTResult:
    """kNN -> RNG^kmax -> reweight-all-mpts -> batched Borůvka, no extraction.

    A thin composition over the resolved ``plan``: every stage is a device
    program placed by the plan (single device or mesh), and each stage ends
    at exactly one named ``engine.to_host`` materialization — ``knn`` (the
    host view stored on the result, which also feeds the WSPD control
    plane), ``graph`` (inside build_rng_graph), and ``mst`` (the final MST
    compaction, the MST stage's single device->host sync; the row masks are
    compacted to (R, n-1) edge ids on device first).
    """
    plan = plan if isinstance(plan, engine.Plan) else engine.resolve_plan(plan, backend=backend)
    x_host = engine.io.ensure_host(x)
    x = jnp.asarray(x_host)
    n = x.shape[0]
    if kmax < 2 or kmax > n:
        raise ValueError(f"kmax must be in [2, n]; got {kmax} (n={n})")
    mpts_list = list(mpts_values) if mpts_values is not None else list(range(kmin, kmax + 1))
    if any(m < 1 or m > kmax for m in mpts_list):
        raise ValueError(f"mpts values must lie in [1, kmax]; got {mpts_list}")
    timings: dict[str, float] = {}

    t0 = time.monotonic()
    knn_d2, knn_idx = plan.knn(x, kmax - 1, x_host=x_host)
    cd2_dev = mrd_mod.core_distances2(knn_d2)
    knn_host, knn_idx_host, cd2 = engine.to_host((knn_d2, knn_idx, cd2_dev), "knn")
    timings["knn"] = time.monotonic() - t0

    t0 = time.monotonic()
    graph = build_rng_graph(
        x,
        knn_d2,
        knn_idx,
        variant=variant,
        plan=plan,
        x_host=x_host,
        cd_kmax_host=np.sqrt(cd2[:, -1].astype(np.float64)),
        knn_d2_host=knn_host,
        knn_idx_host=knn_idx_host,
    )
    timings["rng_build"] = time.monotonic() - t0

    # quantize the edge count so the Borůvka/reweight programs compile one
    # shape per scale bucket instead of one per dataset; padded edges are
    # (0, 0) with +inf weight — same component, never cross, never chosen
    m_real = len(graph.edges)
    m_pad = max(4096, -(-m_real // 4096) * 4096)
    ea = jnp.zeros((m_pad,), jnp.int32).at[:m_real].set(
        jnp.asarray(graph.edges[:, 0], jnp.int32)
    )
    eb = jnp.zeros((m_pad,), jnp.int32).at[:m_real].set(
        jnp.asarray(graph.edges[:, 1], jnp.int32)
    )
    d2_pad = jnp.full((m_pad,), jnp.inf, jnp.float32).at[:m_real].set(
        jnp.asarray(graph.d2)
    )

    t0 = time.monotonic()
    row_idx = jnp.asarray([m - 1 for m in mpts_list])
    if plan.sharded:
        w_range = mrd_mod.reweight_all_mpts(d2_pad, cd2_dev, ea, eb)
        w_sel = w_range[row_idx]
        in_mst = plan.mst_range(ea, eb, w_sel, n=n)
        mst_dev = _compact_mst_rows(in_mst, ea, eb, w_sel, n=n)
    else:
        # single device: reweight + Borůvka + row compaction fused into one
        # program (each row's mask compacts to (n-1) ascending edge ids via
        # cumsum-positioned scatters), ending at the stage's one host sync
        mst_dev = _mst_stage_local(d2_pad, cd2_dev, ea, eb, row_idx, n=n)
    mst_ea, mst_eb, mst_w, counts = engine.to_host(mst_dev, "mst")
    if not np.all(counts == n - 1):
        # Borůvka exits via progressed=False on a disconnected edge list and
        # returns < n-1 edges per row; consuming those rows downstream would
        # feed garbage into linkage.  The RNG^kmax provably contains every
        # per-mpts MST (paper Cor. 1), so disconnection here always means an
        # upstream candidate/filter bug (or a hand-fed broken edge list) —
        # fail loudly instead.
        bad = {
            mpts_list[i]: int(counts[i])
            for i in np.flatnonzero(counts != n - 1)
        }
        raise RuntimeError(
            f"MST incomplete: graph variant {variant!r} with "
            f"{m_real} edges is disconnected — got "
            f"{{mpts: n_tree_edges}} = {bad}, need {n - 1} edges per mpts. "
            f"The RNG^kmax must contain every MST, so this indicates an "
            f"upstream candidate-generation or filter bug."
        )
    timings["mst_range"] = time.monotonic() - t0

    return MultiMSTResult(
        n=n,
        kmax=kmax,
        mpts_values=mpts_list,
        graph=graph,
        knn_d2=knn_host,
        knn_idx=knn_idx_host,
        cd2=cd2,
        mst_ea=mst_ea,
        mst_eb=mst_eb,
        mst_w=mst_w,
        timings=timings,
    )


def linkage_range(msts: MultiMSTResult) -> LinkageRange:
    """All of the range's dendrograms in one batched device program.

    Row i of the result corresponds to ``msts.mpts_values[i]``.
    """
    left, right, height, size = linkage.single_linkage_batch(
        msts.mst_ea, msts.mst_eb, msts.mst_w, n=msts.n
    )
    return LinkageRange(
        left=np.asarray(left),
        right=np.asarray(right),
        height=np.asarray(height),
        size=np.asarray(size),
    )


# -- artifact pack/unpack ----------------------------------------------------
#
# The fitted device state is host numpy by the time it lives on a
# MultiMSTResult (every stage ends at a named engine.to_host point), so an
# artifact is a flat dict of arrays plus a small JSON-able meta dict.  The
# api.FittedModel save/load layer owns the file format; these two functions
# own WHAT constitutes the fitted state, so a field added to MultiMSTResult
# fails loudly here instead of silently vanishing from artifacts.


def pack_msts(msts: MultiMSTResult) -> tuple[dict[str, np.ndarray], dict]:
    """Split a MultiMSTResult into (arrays, meta) for serialization.

    ``arrays`` values are host numpy (``engine.io.ensure_host`` guards
    against device arrays sneaking in); ``meta`` is JSON-serializable.
    """
    arrays = {
        "knn_d2": msts.knn_d2,
        "knn_idx": msts.knn_idx,
        "cd2": msts.cd2,
        "mst_ea": msts.mst_ea,
        "mst_eb": msts.mst_eb,
        "mst_w": msts.mst_w,
        "mpts_values": np.asarray(msts.mpts_values, np.int64),
    }
    meta: dict = {
        "n": int(msts.n),
        "kmax": int(msts.kmax),
        "timings": {k: float(v) for k, v in msts.timings.items()},
        "graph": None,
    }
    if msts.graph is not None:
        arrays["graph_edges"] = msts.graph.edges
        arrays["graph_d2"] = msts.graph.d2
        arrays["graph_w2_kmax"] = msts.graph.w2_kmax
        meta["graph"] = {
            "variant": msts.graph.variant,
            "n_points": int(msts.graph.n_points),
            "stats": {
                k: (int(v) if isinstance(v, (int, np.integer)) else v)
                for k, v in msts.graph.stats.items()
            },
        }
    return (
        {k: engine.io.ensure_host(v) for k, v in arrays.items()},
        meta,
    )


def unpack_msts(arrays: dict[str, np.ndarray], meta: dict) -> MultiMSTResult:
    """Inverse of ``pack_msts``; raises KeyError on missing array fields."""
    graph = None
    if meta.get("graph") is not None:
        g = meta["graph"]
        graph = RngGraph(
            edges=arrays["graph_edges"],
            d2=arrays["graph_d2"],
            w2_kmax=arrays["graph_w2_kmax"],
            variant=g["variant"],
            n_points=int(g["n_points"]),
            stats=dict(g["stats"]),
        )
    return MultiMSTResult(
        n=int(meta["n"]),
        kmax=int(meta["kmax"]),
        mpts_values=[int(m) for m in arrays["mpts_values"]],
        graph=graph,
        knn_d2=arrays["knn_d2"],
        knn_idx=arrays["knn_idx"],
        cd2=arrays["cd2"],
        mst_ea=arrays["mst_ea"],
        mst_eb=arrays["mst_eb"],
        mst_w=arrays["mst_w"],
        timings={k: float(v) for k, v in meta.get("timings", {}).items()},
    )


def extract_one_from_linkage(
    msts: MultiMSTResult,
    lk: LinkageRange,
    row: int,
    *,
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
    cluster_selection_epsilon: float = 0.0,
    policy=None,
) -> HierarchyResult:
    """Vectorized condense/select/label for one mpts row of a LinkageRange.

    ``policy`` (an ``api.selection.SelectionPolicy``, duck-typed so core
    never imports the api layer) bundles the four selection knobs; when
    given it overrides the individual keyword arguments (its
    ``min_cluster_size=None`` falls through to the per-mpts default).
    """
    if policy is not None:
        cluster_selection_method = policy.method
        cluster_selection_epsilon = policy.epsilon
        allow_single_cluster = policy.allow_single_cluster
        if policy.min_cluster_size is not None:
            min_cluster_size = policy.min_cluster_size
    mpts = msts.mpts_values[row]
    mcs = min_cluster_size if min_cluster_size is not None else max(2, mpts)
    Z = linkage.linkage_to_Z(lk.left[row], lk.right[row], lk.height[row], lk.size[row])
    tree = hierarchy.condense_tree_fast(Z, msts.n, mcs)
    stab = hierarchy.compute_stability_fast(tree)
    selected = hierarchy.extract_clusters(
        tree,
        stab,
        allow_single_cluster=allow_single_cluster,
        cluster_selection_method=cluster_selection_method,
        cluster_selection_epsilon=cluster_selection_epsilon,
    )
    labels, lam_pt = hierarchy.labels_for_fast(tree, selected)
    return HierarchyResult(
        mpts=mpts,
        labels=labels,
        n_clusters=int(labels.max()) + 1,
        condensed=tree,
        stability=stab,
        mst_ea=msts.mst_ea[row].astype(np.int64),
        mst_eb=msts.mst_eb[row].astype(np.int64),
        mst_w=msts.mst_w[row],
        selected=selected,
        point_lambda=lam_pt,
    )


def extract_hierarchies(
    msts: MultiMSTResult,
    *,
    lk: LinkageRange | None = None,
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
    cluster_selection_epsilon: float = 0.0,
    policy=None,
) -> tuple[list[HierarchyResult], dict[str, float]]:
    """Batched extraction of the whole range; returns (hierarchies, timings)."""
    timings: dict[str, float] = {}
    t0 = time.monotonic()
    if lk is None:
        lk = linkage_range(msts)
    timings["hierarchy_linkage"] = time.monotonic() - t0

    t0 = time.monotonic()
    out = [
        extract_one_from_linkage(
            msts,
            lk,
            row,
            min_cluster_size=min_cluster_size,
            allow_single_cluster=allow_single_cluster,
            cluster_selection_method=cluster_selection_method,
            cluster_selection_epsilon=cluster_selection_epsilon,
            policy=policy,
        )
        for row in range(len(msts.mpts_values))
    ]
    timings["hierarchy_condense"] = time.monotonic() - t0
    timings["hierarchy"] = timings["hierarchy_linkage"] + timings["hierarchy_condense"]
    return out, timings


def multi_hdbscan(
    x,
    kmax: int,
    *,
    kmin: int = 2,
    variant: str = "rng_star",
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
    cluster_selection_epsilon: float = 0.0,
    backend: str | None = None,
    compute_hierarchies: bool = True,
    mpts_values: Sequence[int] | None = None,
    plan: "engine.Plan | str | None" = None,
) -> MultiDensityResult:
    """All HDBSCAN* hierarchies for mpts in [kmin, kmax] via one RNG^kmax."""
    _validate_min_cluster_size(min_cluster_size)
    msts = fit_msts(
        x, kmax, kmin=kmin, variant=variant, backend=backend,
        mpts_values=mpts_values, plan=plan,
    )
    timings = dict(msts.timings)
    hierarchies: list[HierarchyResult] = []
    if compute_hierarchies:
        hierarchies, t_extract = extract_hierarchies(
            msts,
            min_cluster_size=min_cluster_size,
            allow_single_cluster=allow_single_cluster,
            cluster_selection_method=cluster_selection_method,
            cluster_selection_epsilon=cluster_selection_epsilon,
        )
        timings.update(t_extract)
    else:
        timings["hierarchy"] = 0.0
    timings["total"] = (
        timings["knn"] + timings["rng_build"] + timings["mst_range"] + timings["hierarchy"]
    )

    return MultiDensityResult(
        n=msts.n,
        kmax=kmax,
        mpts_values=msts.mpts_values,
        graph=msts.graph,
        knn_d2=msts.knn_d2,
        knn_idx=msts.knn_idx,
        cd2=msts.cd2,
        hierarchies=hierarchies,
        timings=timings,
    )


def hdbscan_baseline(
    x,
    mpts_values: Sequence[int],
    *,
    kmax: int | None = None,
    min_cluster_size: int | None = None,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
    cluster_selection_epsilon: float = 0.0,
    backend: str | None = None,
    compute_hierarchies: bool = True,
    plan: "engine.Plan | str | None" = None,
) -> tuple[list[HierarchyResult], dict[str, float]]:
    """Paper's baseline: shared kNN pass + dense complete-graph MST per mpts."""
    _validate_min_cluster_size(min_cluster_size)
    plan = plan if isinstance(plan, engine.Plan) else engine.resolve_plan(plan, backend=backend)
    x_host = engine.io.ensure_host(x)
    x = jnp.asarray(x_host)
    n = x.shape[0]
    mpts_list = list(mpts_values)
    kmax = kmax or max(mpts_list)
    timings: dict[str, float] = {}

    t0 = time.monotonic()
    knn_d2, _ = plan.knn(x, kmax - 1, x_host=x_host)
    cd2 = mrd_mod.core_distances2(knn_d2)
    cd2.block_until_ready()
    timings["knn"] = time.monotonic() - t0

    t_mst = 0.0
    eb = np.arange(1, n, dtype=np.int32)
    mst_ea = np.zeros((len(mpts_list), n - 1), np.int32)
    mst_w = np.zeros((len(mpts_list), n - 1), np.float32)
    for row, mpts in enumerate(mpts_list):
        t0 = time.monotonic()
        src, w2 = boruvka.prim_dense_mst(x, cd2[:, mpts - 1])
        w2.block_until_ready()
        t_mst += time.monotonic() - t0
        mst_ea[row] = np.asarray(src)[1:]
        mst_w[row] = np.sqrt(np.asarray(w2)[1:])
    timings["mst"] = t_mst

    results: list[HierarchyResult] = []
    t0 = time.monotonic()
    if compute_hierarchies:
        msts = MultiMSTResult(
            n=n,
            kmax=kmax,
            mpts_values=mpts_list,
            graph=None,
            knn_d2=np.asarray(knn_d2),
            knn_idx=np.zeros((n, 0), np.int32),
            cd2=np.asarray(cd2),
            mst_ea=mst_ea,
            mst_eb=np.broadcast_to(eb, mst_ea.shape),
            mst_w=mst_w,
            timings={},
        )
        results, _ = extract_hierarchies(
            msts,
            min_cluster_size=min_cluster_size,
            allow_single_cluster=allow_single_cluster,
            cluster_selection_method=cluster_selection_method,
            cluster_selection_epsilon=cluster_selection_epsilon,
        )
    timings["hierarchy"] = time.monotonic() - t0
    timings["total"] = timings["knn"] + t_mst + timings["hierarchy"]
    return results, timings
