"""The `Plan`: backend + optional mesh + chunk/tile sizes, resolved once.

A Plan is the single value threaded through every pipeline stage; stages ask
it "run the kNN", "run the lune check", "run the MST range" and never look at
the hardware themselves.  Placement resolution follows the
``dist.sharding.resolve_rules`` philosophy — the *request* ("auto" / "single"
/ "mesh") is filtered against the mesh that actually exists, so
``MultiHDBSCAN(mesh=some_mesh)`` degrades gracefully to the single-device
path on a laptop (1-device mesh, or no ``data`` axis) and shards on a pod.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

PLAN_REQUESTS = ("auto", "single", "mesh")

# ---------------------------------------------------------------------------
# Persistent program cache
# ---------------------------------------------------------------------------
#
# The PR-2 pipeline compiled one tile program per (dataset-dependent!) chunk
# shape: the SBCN tier chunks were rounded to the pow2 of each tier's pair
# COUNT and every oversized WSPD pair compiled its own `_sbcn_large` at its
# exact (na, nb) — ~3x more programs than tiers, and none reusable across
# datasets.  Every dispatch family now quantizes its shapes to a fixed
# bucket ladder and registers the program builder here, keyed by
# (family, tier dims, k, d, ...): the first call per key builds (and jits)
# the program, every later call — across stages, Plan instances, and
# datasets — reuses it.  Cold compile cost becomes O(#buckets), not
# O(#datasets x #tiers).

_PROGRAM_CACHE: dict[tuple, Callable] = {}
_PROGRAM_CACHE_LOCK = threading.Lock()


def cached_program(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Return the program registered under ``key``, building it on first use.

    ``key`` must capture everything that determines the compiled program
    besides operand shapes (family name, tier dims, candidate count k,
    point dimensionality d, chunking) — callers guarantee the operand
    shapes are a pure function of the key.
    """
    fn = _PROGRAM_CACHE.get(key)
    if fn is None:
        with _PROGRAM_CACHE_LOCK:
            fn = _PROGRAM_CACHE.get(key)
            if fn is None:
                fn = _PROGRAM_CACHE[key] = build()
    return fn


def program_cache_info() -> list[tuple]:
    """Registered program keys (introspection / tests)."""
    return sorted(_PROGRAM_CACHE, key=repr)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Resolved execution plan for the clustering pipeline.

    ``backend`` is the kernel backend for compute-local dispatch ("pallas",
    "pallas_interpret", "jnp", "ref"); when ``mesh`` is set the row-parallel
    stages (kNN, exact lune scan, Borůvka range) take the mesh path instead
    and ``backend`` still governs any residual local compute.  All chunk and
    tile sizes live here so a deployment can tune them in exactly one place.
    """

    backend: str
    mesh: Any = None            # jax.sharding.Mesh | None (None = single device)
    axis: str = "data"          # mesh axis rows are sharded over
    # -- tile/chunk sizes (device-memory knobs), resolved once --------------
    knn_block_q: int = 256      # pallas kNN query tile
    knn_block_k: int = 256      # pallas kNN key tile
    knn_refine_slack: int = 8   # extra candidates before the exact refine
    lune_block_e: int = 256     # pallas lune-filter edge tile
    lune_block_c: int = 512     # pallas lune-filter candidate tile
    filter_chunk: int = 16384   # kNN-lune filter cascade edge chunk
    sbcn_tile_elems: int = 1 << 22  # elements per SBCN tier-program chunk
    sbcn_pair_cap: int = 1 << 18    # max padded |A|*|B| on the bucketed path
    sbcn_row_chunk: int = 2048      # row chunk for oversized WSPD pairs
    # -- fused cascade (PR 3) ----------------------------------------------
    cascade_tie_cap: int = 3    # bounded per-row SBCN emissions before fallback
    cascade_stage1_k: int = 2   # neighbours in the cheap stage-1 lune prefilter
    cascade_chunk: int = 65536  # edges per fused-cascade program chunk
    cascade_block_e: int = 256  # pallas edge-cascade tile
    tier_chunk_elems: int = 1 << 18  # fixed cells per SBCN emission chunk
    # -- dual-tree Borůvka large-n tier (ISSUE 6) ---------------------------
    candidate_method: str = "auto"  # "auto" | "wspd" | "dualtree"
    dualtree_min_n: int = 20000     # auto tier threshold (candidate stage + kNN)
    dualtree_leaf: int = 4          # fair-split leaf size for the traversals
                                    # (measured optimum: larger leaves weaken
                                    # the node-max prune bound faster than the
                                    # tile batching pays it back)
    dualtree_margin: float = 1e-5   # relative prune/emit margin (f64 vs f32 ties)

    # -- placement ---------------------------------------------------------

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis] if self.mesh is not None else 1

    def use_dualtree(self, n: int) -> bool:
        """Size-tier dispatch for the candidate stages (kNN + graph build).

        ``candidate_method`` forces a tier; ``"auto"`` switches to the
        dual-tree path at ``dualtree_min_n`` points, where the all-pairs
        flavored WSPD/SBCN tile work overtakes the traversal overhead.  The
        small-n tier stays the oracle the dual-tree tests pin against.
        """
        if self.candidate_method == "dualtree":
            return True
        if self.candidate_method == "wspd":
            return False
        if self.candidate_method != "auto":
            raise ValueError(
                f"candidate_method must be 'auto', 'wspd' or 'dualtree'; "
                f"got {self.candidate_method!r}"
            )
        return n >= self.dualtree_min_n

    # -- stage dispatch ----------------------------------------------------

    def knn(self, x, k_top: int, *, x_host=None):
        """(d2 ascending, idx): mesh ring path when sharded, dual-tree
        candidate search + shared exact refine on the large-n single-device
        tier, kernels otherwise.  ``x_host`` feeds the dual-tree host
        control plane without an extra device sync when the caller already
        holds a host view (fit_msts does)."""
        from .. import kernels

        n = int(x.shape[0])
        if not self.sharded and n > 2 and self.use_dualtree(n):
            from ..core import dualtree
            from . import io

            if x_host is None:
                x_host = io.ensure_host(x)
            k_eff = min(n - 1, k_top + self.knn_refine_slack)
            cand = dualtree.knn_candidates(
                x_host,
                k_eff,
                leaf_size=self.dualtree_leaf,
                margin=self.dualtree_margin,
            )
            return kernels.ops.knn_from_candidates(x, cand, k_top=k_top)
        return kernels.ops.knn(
            x,
            k_top,
            backend="mesh" if self.sharded else self.backend,
            mesh=self.mesh,
            mesh_axis=self.axis,
            block_q=self.knn_block_q,
            block_k=self.knn_block_k,
            refine_slack=self.knn_refine_slack,
        )

    def query_knn(self, xq, x, k_top: int):
        """Out-of-sample kNN: query rows ranked against the fitted set.

        Always compute-local: a (q, n) cross sweep with q << n is cheap
        relative to the fit, and the queries arrive on the serving host —
        sharding them over a mesh would cost more in replication traffic
        than the sweep itself.  The backend still follows the plan, and all
        backends share the exact refine pass (prediction parity).
        """
        from .. import kernels

        return kernels.ops.query_knn(
            xq, x, k_top,
            backend=self.backend,
            refine_slack=self.knn_refine_slack,
        )

    def lune_nonempty(self, ea, eb, w2, points, cd2):
        """Exact lune-emptiness verdicts for an edge list, placed per plan."""
        from .. import kernels

        return kernels.ops.lune_nonempty(
            ea,
            eb,
            w2,
            points,
            cd2,
            backend="mesh" if self.sharded else self.backend,
            mesh=self.mesh,
            mesh_axis=self.axis,
            block_e=self.lune_block_e,
            block_c=self.lune_block_c,
        )

    def edge_cascade(self, x, cd2k, knn_idx, knn_d2, ea, eb, valid, *, k_check: int):
        """Fused d2 + w2 + kNN-lune verdict + certificate over an edge list.

        Stage placement: local compute on every plan (the mesh path shards
        points for the kNN/exact-lune/MST stages; the cascade runs on the
        replicated candidate set, like the rest of the RNG build).  Compile
        caching lives in the jitted cascade programs themselves (keyed by
        k_check + operand shapes); the ``cached_program`` registry covers
        the dispatch families that build per-tier callables (core.sbcn).
        """
        from ..kernels import fused_cascade

        return fused_cascade.edge_cascade(
            x, cd2k, knn_idx, knn_d2, ea, eb, valid,
            k_check=k_check,
            backend=self.backend,
            chunk=self.cascade_chunk,
            block_e=self.cascade_block_e,
        )

    def mst_range(self, ea, eb, w_range, *, n: int):
        """All R MSTs; rows (independent mpts values) shard over the mesh."""
        if self.sharded:
            from ..dist import cluster_parallel

            return cluster_parallel.sharded_mst_range(
                ea, eb, w_range, n=n, mesh=self.mesh, axis=self.axis
            )
        from ..core import boruvka

        return boruvka.boruvka_mst_range(ea, eb, w_range, n=n)

    def describe(self) -> str:
        place = (
            f"mesh[{self.axis}={self.n_shards}]" if self.sharded else "single"
        )
        return f"Plan(backend={self.backend!r}, placement={place})"


def _mesh_usable(mesh, axis: str) -> bool:
    """A mesh is worth sharding over iff the row axis exists and is >1."""
    return (
        mesh is not None
        and axis in getattr(mesh, "shape", {})
        and mesh.shape[axis] > 1
    )


def resolve_plan(
    plan: Plan | str | None = "auto",
    *,
    backend: str | None = None,
    mesh=None,
    axis: str = "data",
    **sizes,
) -> Plan:
    """Resolve a plan request against the actual hardware, once.

    ``plan`` is either an already-resolved ``Plan`` (returned as-is), or one
    of the requests:

      * ``"auto"`` (default) — shard iff ``mesh`` has a non-trivial ``axis``;
        otherwise single-device.  This is the laptop==pod path.
      * ``"single"`` — force the single-device path (mesh ignored).
      * ``"mesh"`` — require the mesh path; raises if ``mesh`` is unusable,
        instead of silently degrading.

    ``backend=None`` auto-selects per platform (pallas on TPU, jnp elsewhere).
    Extra keyword args override individual chunk/tile sizes.
    """
    if isinstance(plan, Plan):
        if mesh is not None and plan.mesh is not mesh:
            raise ValueError(
                "got both a pre-built Plan and a different mesh=; build the "
                "Plan against that mesh (resolve_plan(..., mesh=mesh) or "
                "dataclasses.replace(plan, mesh=mesh)) instead of passing both"
            )
        return plan
    if plan is None:
        plan = "auto"
    if plan not in PLAN_REQUESTS:
        raise ValueError(f"plan must be one of {PLAN_REQUESTS} or a Plan; got {plan!r}")

    from .. import kernels

    backend = backend or kernels.ops.default_backend()
    usable = _mesh_usable(mesh, axis)
    if plan == "mesh" and not usable:
        raise ValueError(
            f"plan='mesh' requires a mesh with a non-trivial {axis!r} axis; "
            f"got mesh={mesh!r}"
        )
    use_mesh = usable and plan in ("auto", "mesh")
    return Plan(backend=backend, mesh=mesh if use_mesh else None, axis=axis, **sizes)
