"""Clustering serve engine: correctness under concurrency, micro-batching,
LRU bounds — plus the batched-LM regression tests (per-request temperature,
EOS masking)."""

import threading

import numpy as np
import pytest

from repro.api import MultiHDBSCAN
from repro.serve import ClusterServeEngine


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(41)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(90, 2)),
        rng.normal((4, 0), 0.5, size=(90, 2)),
        rng.normal((2, 4), 0.4, size=(70, 2)),
    ]).astype(np.float32)
    return x


@pytest.fixture(scope="module")
def engine(dataset):
    est = MultiHDBSCAN(kmax=8).fit(dataset)
    eng = ClusterServeEngine(est, max_batch=32, hierarchy_cache_size=3)
    yield eng
    eng.close()


def test_requires_fitted_estimator():
    with pytest.raises(RuntimeError, match="fitted"):
        ClusterServeEngine(MultiHDBSCAN(kmax=4))


def test_serve_predict_matches_estimator(dataset, engine):
    """The serve smoke: engine answers == direct estimator answers."""
    q = dataset[:9] + 0.02
    direct = engine.estimator.approximate_predict(q, mpts=8)
    lab, prob = engine.predict(q, mpts=8)
    np.testing.assert_array_equal(lab, direct[0])
    np.testing.assert_allclose(prob, direct[1])

    res = engine.predict(q)  # full range
    direct_all = engine.estimator.approximate_predict(q)
    np.testing.assert_array_equal(res.labels, direct_all.labels)


def test_concurrent_clients_are_microbatched(dataset, engine):
    """Many concurrent single-row clients: every answer correct, and the
    engine fuses them into far fewer device batches than requests."""
    rng = np.random.default_rng(43)
    queries = [
        (dataset[rng.integers(len(dataset))] + 0.01).astype(np.float32)
        for _ in range(24)
    ]
    direct = engine.estimator.approximate_predict(np.stack(queries), mpts=6)

    before = engine.stats()
    results: dict[int, tuple] = {}

    def client(i):
        results[i] = engine.predict(queries[i], mpts=6)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(24)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    after = engine.stats()

    for i in range(24):
        lab, prob = results[i]
        assert lab[0] == direct[0][i]
        assert prob[0] == pytest.approx(direct[1][i])
    n_batches = after["n_batches"] - before["n_batches"]
    assert n_batches < 24, f"no micro-batching: {n_batches} batches for 24 requests"
    assert after["n_queries"] - before["n_queries"] == 24


def test_mixed_mpts_requests_share_one_batch(dataset, engine):
    """Riders asking for different levels still fuse into one device pass."""
    before = engine.stats()
    futs = [
        engine.submit_predict(dataset[:2] + 0.01, mpts=m) for m in (4, 5, 6, 7)
    ]
    outs = [f.result(timeout=60) for f in futs]
    for m, (lab, _) in zip((4, 5, 6, 7), outs):
        direct = engine.estimator.approximate_predict(dataset[:2] + 0.01, mpts=m)
        np.testing.assert_array_equal(lab, direct[0])
    assert engine.stats()["n_batches"] - before["n_batches"] <= 2


def test_labels_profile_and_selection_override(dataset, engine):
    est = engine.estimator
    np.testing.assert_array_equal(engine.labels(8), est.labels_for(8))
    leaf = engine.labels(8, cluster_selection_method="leaf")
    assert leaf.max() >= est.labels_for(8).max()  # leaf refines eom
    # the override never disturbs the estimator's own configuration
    np.testing.assert_array_equal(engine.labels(8), est.labels_for(8))

    prof = engine.profile()
    assert [r["mpts"] for r in prof] == est.mpts_values_
    dbcv = engine.dbcv_profile()
    assert all(-1.0 <= r["dbcv"] <= 1.0 for r in dbcv)
    m = engine.membership(5)
    np.testing.assert_array_equal(m.labels, est.labels_for(5))


def test_hierarchy_cache_is_lru_bounded(dataset, engine):
    for m in engine.estimator.mpts_values_:
        engine.labels(m)
    cache = engine.estimator._hierarchy_cache
    assert len(cache) <= 3
    # most recently served levels survive
    assert engine.estimator.mpts_values_[-1] in cache
    # evicted levels still answer correctly (re-extracted on demand)
    lab2 = engine.labels(2)
    np.testing.assert_array_equal(lab2, engine.estimator.labels_for(2))


def test_invalid_requests_fail_alone_at_submit_time(dataset, engine):
    """A malformed request is rejected before enqueueing: it must never
    reach the micro-batcher, where its failure would poison co-batched
    strangers' futures."""
    with pytest.raises(KeyError, match="not in computed range"):
        engine.submit_predict(dataset[:1], mpts=99)
    with pytest.raises(ValueError, match="features"):
        engine.submit_predict(np.zeros((1, 7), np.float32), mpts=8)
    bad = dataset[:1].copy()
    bad[0, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        engine.submit_predict(bad, mpts=8)
    # a healthy rider submitted right after still succeeds
    lab, _ = engine.predict(dataset[:1], mpts=8)
    assert lab.shape == (1,)


def test_engine_rejects_degenerate_cache_size(dataset):
    est = MultiHDBSCAN(kmax=4).fit(dataset)
    with pytest.raises(ValueError, match="hierarchy_cache_size"):
        ClusterServeEngine(est, hierarchy_cache_size=0)


def test_closed_engine_rejects_requests(dataset):
    est = MultiHDBSCAN(kmax=4).fit(dataset)
    eng = ClusterServeEngine(est)
    eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.predict(dataset[:1])


def test_stats_shape(engine):
    s = engine.stats()
    for k in ("n_requests", "n_queries", "n_batches", "p50_ms", "p95_ms",
              "queries_per_s", "mean_batch"):
        assert k in s
    assert s["p95_ms"] >= s["p50_ms"] >= 0.0


def test_engine_loads_from_artifact_and_matches_fresh(dataset, engine, tmp_path):
    """Satellite acceptance: ClusterServeEngine.load boots from a saved
    FittedModel artifact — zero refit, zero raw-data access — and answers
    predict/labels identically to the freshly-fitted engine."""
    path = engine.model.save(str(tmp_path / "served.npz"))
    q = dataset[:7] + 0.03
    with ClusterServeEngine.load(
        path, serve_options={"max_batch": 16, "hierarchy_cache_size": 4}
    ) as loaded:
        assert loaded.estimator is None  # model-only boot, no estimator
        for mpts in (2, 5, 8):
            np.testing.assert_array_equal(
                loaded.labels(mpts), engine.labels(mpts), err_msg=f"mpts={mpts}"
            )
            lab_l, prob_l = loaded.predict(q, mpts=mpts)
            lab_f, prob_f = engine.predict(q, mpts=mpts)
            np.testing.assert_array_equal(lab_l, lab_f)
            np.testing.assert_array_equal(prob_l, prob_f)
        res_l, res_f = loaded.predict(q), engine.predict(q)  # full range
        np.testing.assert_array_equal(res_l.labels, res_f.labels)
        np.testing.assert_array_equal(res_l.probabilities, res_f.probabilities)


def test_engine_load_pins_expected_config(dataset, engine, tmp_path):
    from repro.api import ArtifactError

    path = engine.model.save(str(tmp_path / "pinned.npz"))
    with ClusterServeEngine.load(
        path, expect_config_hash=engine.model.config_hash
    ) as eng:
        assert eng.model.config_hash == engine.model.config_hash
    with pytest.raises(ArtifactError, match="does not match the expected"):
        ClusterServeEngine.load(path, expect_config_hash="f" * 16)


def test_per_request_selection_policy(dataset, engine):
    """A SelectionPolicy rides along per request — predict and labels — and
    never disturbs the engine's default configuration."""
    from repro.api import SelectionPolicy

    model = engine.model
    leaf = SelectionPolicy(method="leaf")
    np.testing.assert_array_equal(
        engine.labels(8, policy=leaf), model.select(8, leaf).labels
    )
    eps = SelectionPolicy(method="leaf", epsilon=1.0)
    np.testing.assert_array_equal(
        engine.labels(8, policy=eps), model.select(8, eps).labels
    )
    with pytest.raises(ValueError, match="not both"):
        engine.labels(8, policy=leaf, cluster_selection_method="eom")

    q = dataset[:5] + 0.02
    lab_leaf, prob_leaf = engine.predict(q, mpts=8, policy=leaf)
    direct = model.approximate_predict(q, mpts=8, policy=leaf)
    np.testing.assert_array_equal(lab_leaf, direct[0])
    np.testing.assert_allclose(prob_leaf, direct[1])
    # default-policy answers are unchanged afterwards
    np.testing.assert_array_equal(engine.labels(8), model.select(8).labels)
    m = engine.membership(8, policy=leaf)
    np.testing.assert_array_equal(m.labels, model.select(8, leaf).labels)


# ---------------------------------------------------------------------------
# Batched LM engine regressions (serve/lm.py)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_engine():
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.lm import Engine

    cfg = get_config("qwen2_1_5b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, max_len=64)


def test_lm_mixed_temperature_batch(lm_engine):
    """Regression: a batch must apply each request's OWN temperature — the
    old loop broadcast requests[0].temperature, so a greedy request batched
    behind a hot one silently got sampled."""
    from repro.serve.lm import GenRequest

    greedy = GenRequest(prompt=np.array([0, 5, 9], np.int32), max_new_tokens=8,
                        temperature=0.0)
    hot = GenRequest(prompt=np.array([0, 7], np.int32), max_new_tokens=8,
                     temperature=1.5)
    solo = lm_engine.generate([greedy], seed=0)[0]
    m1 = lm_engine.generate([hot, greedy], seed=1)
    m2 = lm_engine.generate([hot, greedy], seed=2)
    # the greedy row is deterministic regardless of batch company and seed
    np.testing.assert_array_equal(m1[1], solo)
    np.testing.assert_array_equal(m2[1], solo)
    # while the hot row really is sampling
    assert not np.array_equal(m1[0], m2[0])


def test_lm_eos_masking_and_stats(lm_engine):
    """Regression: rows that hit EOS keep emitting EOS (no post-EOS junk)
    and the throughput stats count only real generated tokens."""
    from repro.serve.lm import GenRequest

    base = GenRequest(prompt=np.array([0, 5, 9], np.int32), max_new_tokens=8,
                      temperature=0.0)
    solo = lm_engine.generate([base], seed=0)[0]
    eos_tok = int(solo[0])  # make the first generated token the EOS

    early = GenRequest(prompt=np.array([0, 5, 9], np.int32), max_new_tokens=8,
                       temperature=0.0, eos_id=eos_tok)
    # same prompt length as `early`, so the solo run sees identical padding
    other = GenRequest(prompt=np.array([0, 7, 4], np.int32), max_new_tokens=8,
                       temperature=0.0)
    outs = lm_engine.generate([early, other], seed=0)
    stats = lm_engine.last_stats
    assert len(outs[0]) == 1 and outs[0][0] == eos_tok
    assert stats["tokens"] == len(outs[0]) + len(outs[1])
    assert stats["tok_per_s"] > 0
    # the laggard row is unaffected by its finished neighbour
    np.testing.assert_array_equal(outs[1], lm_engine.generate([other], seed=0)[0])
