"""Well-Separated Pair Decomposition with the paper's mrd-aware predicate.

Host-side control plane (numpy): the fair-split tree and the pair recursion
are pointer-chasing scalar work — O(n log n) node operations — which a real
accelerator deployment keeps on the driver CPU (DESIGN.md §3).  All O(n^2)
distance work consumes the *output* of this module on device.

Well-separation (paper §IV-E, adapting Callahan-Kosaraju):

    D(A, B) >= s * max{ diam(B_A), diam(B_B), max_{p in A u B} c_kmax(p) }

where ``B_X`` is the ball circumscribing the bounding box of X and ``D`` is
the (lower-bounded) distance between the two balls.  ``s = 1``.

Termination note: with the core-distance term two *singleton* nodes can be
impossible to separate (d(a,b) < max core dist) and cannot be split further;
such pairs are emitted anyway — for singletons the pair IS its own SBCN edge,
so emitting it preserves the RNG-superset property (it only ever ADDS a
candidate edge).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FairSplitTree:
    """Array-encoded fair-split tree over a permutation of point indices."""

    perm: np.ndarray        # (n,)  point indices, contiguous per node
    start: np.ndarray       # (n_nodes,) range start into perm
    end: np.ndarray         # (n_nodes,) range end (exclusive)
    left: np.ndarray        # (n_nodes,) child id or -1
    right: np.ndarray       # (n_nodes,)
    center: np.ndarray      # (n_nodes, d) bbox center
    radius: np.ndarray      # (n_nodes,)  half bbox diagonal (ball radius)
    max_cd: np.ndarray      # (n_nodes,)  max core distance (NOT squared) in node

    @property
    def n_nodes(self) -> int:
        return self.start.shape[0]

    def points(self, u: int) -> np.ndarray:
        return self.perm[self.start[u] : self.end[u]]


def build_fair_split_tree(
    x: np.ndarray, cd_kmax: np.ndarray, *, leaf_size: int = 1
) -> FairSplitTree:
    """Midpoint-split fair-split tree; leaves hold <= ``leaf_size`` points.

    Level-synchronous build: every level processes ALL of its nodes with
    whole-array numpy (``reduceat`` over the contiguous perm ranges + one
    stable per-level partition sort), so the host control plane costs
    O(depth) vectorized passes instead of one Python iteration per node.

    ``leaf_size=1`` (the default) is the WSPD configuration (singleton
    leaves, required by the pair recursion's termination argument);
    ``core.dualtree`` builds with larger leaves so its traversals bottom out
    in batched tile evaluations instead of per-point node pairs.
    """
    if leaf_size < 1:
        raise ValueError(f"leaf_size must be >= 1; got {leaf_size}")
    n, d = x.shape
    max_nodes = 2 * n - 1
    perm = np.arange(n)
    start = np.zeros(max_nodes, np.int64)
    end = np.zeros(max_nodes, np.int64)
    left = np.full(max_nodes, -1, np.int64)
    right = np.full(max_nodes, -1, np.int64)
    centers = np.zeros((max_nodes, d), np.float64)
    radii = np.zeros(max_nodes, np.float64)
    max_cd = np.zeros(max_nodes, np.float64)

    node_count = 1
    start[0], end[0] = 0, n
    level = np.array([0], np.int64)
    while len(level):
        s, e = start[level], end[level]                     # (L,) ranges
        xp = x[perm]                                        # level's point view
        cdp = cd_kmax[perm]
        # Segment min/max via reduceat over interleaved (start, end)
        # boundaries: level ranges are disjoint, so sorted by start the
        # boundary list is non-decreasing and the EVEN segments are exactly
        # the ranges (odd segments are inter-range gaps, discarded).
        o = np.argsort(s, kind="stable")
        so, eo = s[o], e[o]
        bounds = np.empty(2 * len(so), np.int64)
        bounds[0::2] = so
        bounds[1::2] = eo
        if bounds[-1] == n:  # reduceat boundaries must be < n; the last
            bounds = bounds[:-1]  # segment then runs to the array end anyway
        lo_o = np.minimum.reduceat(xp, bounds, axis=0)[0::2]
        hi_o = np.maximum.reduceat(xp, bounds, axis=0)[0::2]
        cd_o = np.maximum.reduceat(cdp, bounds)[0::2]
        inv = np.empty_like(o)
        inv[o] = np.arange(len(o))
        lo = lo_o[inv]
        hi = hi_o[inv]
        cdmax = cd_o[inv]

        centers[level] = (lo + hi) / 2.0
        radii[level] = 0.5 * np.sqrt(((hi - lo) ** 2).sum(axis=1))
        max_cd[level] = cdmax

        sz = e - s
        split = sz > leaf_size
        if not split.any():
            break
        sp = level[split]
        lo_s, hi_s = lo[split], hi[split]
        dim = np.argmax(hi_s - lo_s, axis=1)
        mid = 0.5 * (lo_s[np.arange(len(sp)), dim] + hi_s[np.arange(len(sp)), dim])

        # per-position node id + split params, for one vectorized partition
        L = len(sp)
        pos_node = np.full(n, -1, np.int64)          # index into sp, else -1
        reps = (e[split] - s[split]).astype(np.int64)
        pos_idx = np.repeat(s[split], reps) + _ranges_concat(reps)
        pos_node[pos_idx] = np.repeat(np.arange(L), reps)
        active = pos_node >= 0
        ai = np.nonzero(active)[0]
        anode = pos_node[ai]
        aval = x[perm[ai], dim[anode]]
        left_mask = aval <= mid[anode]
        # degenerate nodes (all/none on one side): median split by order
        n_left = np.bincount(anode, weights=left_mask, minlength=L).astype(np.int64)
        degenerate = (n_left == 0) | (n_left == reps)
        if degenerate.any():
            # stable rank of each position within its node, by (val, pos)
            order_in = np.lexsort((ai, aval, anode))
            rank = np.empty(len(ai), np.int64)
            rank[order_in] = _ranges_concat(reps)
            half = reps // 2
            med_mask = rank < half[anode]
            deg_pos = degenerate[anode]
            left_mask = np.where(deg_pos, med_mask, left_mask)
            n_left = np.bincount(anode, weights=left_mask, minlength=L).astype(np.int64)
        # stable partition: destination positions (ascending ai) group by
        # node RANGE order, so the source must sort by range start — not by
        # node index, which interleaves across the level
        new_order = np.lexsort((ai, ~left_mask, s[split][anode]))
        perm[ai] = perm[ai[new_order]]

        lid = node_count + 2 * np.arange(L)
        rid = lid + 1
        node_count += 2 * L
        left[sp], right[sp] = lid, rid
        start[lid], end[lid] = s[split], s[split] + n_left
        start[rid], end[rid] = s[split] + n_left, e[split]
        level = np.concatenate([lid, rid])

    sl = slice(0, node_count)
    return FairSplitTree(
        perm=perm,
        start=start[sl].copy(),
        end=end[sl].copy(),
        left=left[sl].copy(),
        right=right[sl].copy(),
        center=centers[sl].copy(),
        radius=radii[sl].copy(),
        max_cd=max_cd[sl].copy(),
    )


def _ranges_concat(lens: np.ndarray) -> np.ndarray:
    """concatenate([arange(l) for l in lens]) without the Python loop."""
    total = int(lens.sum())
    out = np.arange(total, dtype=np.int64)
    offsets = np.repeat(np.cumsum(lens) - lens, lens)
    return out - offsets


def wspd_pairs(tree: FairSplitTree, s: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
    """Enumerate well-separated pairs w.r.t. the mrd predicate.

    Level-synchronous vectorized search: the work list of candidate (u, v)
    node pairs is processed as whole numpy arrays per round (the recursion
    depth is O(log n + split chain), so ~tens of rounds regardless of the
    pair count).  Returns (U, V) arrays of node ids.
    """
    center, radius, max_cd = tree.center, tree.radius, tree.max_cd
    left, right = tree.left, tree.right
    size = tree.end - tree.start

    internal = np.nonzero(left != -1)[0]
    U = left[internal]
    V = right[internal]
    out_u: list[np.ndarray] = []
    out_v: list[np.ndarray] = []
    while len(U):
        # singleton-singleton pairs are emitted whether separated or not
        # (module docstring): short-circuit them before any separation math —
        # they dominate the worklist in dense regions
        ss = (size[U] == 1) & (size[V] == 1)
        if ss.any():
            out_u.append(U[ss])
            out_v.append(V[ss])
            U, V = U[~ss], V[~ss]
            if not len(U):
                break
        rU, rV = radius[U], radius[V]                       # gather once per round
        diff = center[U] - center[V]
        d_centers = np.sqrt(np.einsum("ij,ij->i", diff, diff))
        dist_lb = np.maximum(0.0, d_centers - rU - rV)
        rhs = s * np.maximum(
            2.0 * np.maximum(rU, rV), np.maximum(max_cd[U], max_cd[V])
        )
        emit = dist_lb >= rhs
        out_u.append(U[emit])
        out_v.append(V[emit])
        keep = ~emit
        U, V, rU, rV = U[keep], V[keep], rU[keep], rV[keep]
        if not len(U):
            break
        # split the "bigger" node (by ball radius, then size)
        su = (rU > rV) | ((rU == rV) & (size[U] >= size[V]))
        Us, Vs = U[su], V[su]
        Uo, Vo = U[~su], V[~su]
        U = np.concatenate([left[Us], right[Us], Uo, Uo])
        V = np.concatenate([Vs, Vs, left[Vo], right[Vo]])
    return np.concatenate(out_u), np.concatenate(out_v)
