"""Clustering serve engine: fit once (or load an artifact), answer traffic.

The ROADMAP north-star ("serve heavy traffic from millions of users") gets
its clustering-shaped surface here: a process-resident engine over ONE
:class:`~repro.api.FittedModel` whose fitted multi-MST state answers three
request families —

  * ``predict``  — out-of-sample assignment of query points (any subset of
    the fitted mpts range, or all of it),
  * ``labels`` / ``membership`` — the fitted labelling at one density level,
    with an optional per-request :class:`~repro.api.SelectionPolicy`
    (eom/leaf, Malzer & Baum's epsilon threshold, min_cluster_size — cheap
    per-query re-selection over the same cached linkage),
  * ``profile`` / ``dbcv_profile`` — whole-range summaries.

Scale-out is refit-free: ``ClusterServeEngine.load(path)`` boots a worker
from a saved ``FittedModel`` artifact — the fit happens once, anywhere, and
any number of serve processes ``load()`` the npz in milliseconds.

Requests enter a queue from any number of client threads; ONE worker thread
owns the model (no lock on the fitted state) and **micro-batches**
concurrent predict requests: after the first request lands it waits up to
``max_delay_ms`` for company, then concatenates up to ``max_batch`` query
rows into a single device pass — one ``query_knn`` + attach program serves
every rider, whatever mix of mpts values they asked for (riders with
different selection *policies* share the device pass group-by-group: the
attach stage is policy-independent, only the host tree walk differs).
Per-(mpts, policy) hierarchy extractions are LRU-bounded
(``hierarchy_cache_size``) so a hostile query mix cannot hold all R
condensed trees resident.

``benchmarks/run.py`` drives this engine for the ``serve`` section of
``BENCH_pipeline.json`` (warm p50/p95 latency, queries/s).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Sequence

import numpy as np

from ..api.model import FittedModel
from ..api.selection import SelectionPolicy
from ..core import predict


@dataclasses.dataclass
class _Pending:
    kind: str                   # "predict" | "labels" | "membership" | "profile" | "dbcv"
    future: Future
    t_submit: float
    q: np.ndarray | None = None
    mpts: int | None = None
    policy: SelectionPolicy | None = None   # per-request selection override


class ClusterServeEngine:
    """Process-resident serving over one fitted model.

    Parameters
    ----------
    model : repro.api.FittedModel or a *fitted* repro.api.MultiHDBSCAN
        The fitted state to serve.  The engine takes ownership: it installs
        its LRU bound on the model's extraction cache and serializes all
        access through its worker.  Passing an estimator keeps the legacy
        construction path working (the engine serves its ``model_``).
    max_batch : int
        Max query rows fused into one predict device pass.
    max_delay_ms : float
        How long the worker holds the first predict request of a batch
        waiting for riders.  The knob trades p50 latency for throughput.
    hierarchy_cache_size : int
        LRU bound on cached per-(mpts, policy) extractions (and their walk
        tables).
    """

    def __init__(
        self,
        model,
        *,
        max_batch: int = 64,
        max_delay_ms: float = 2.0,
        hierarchy_cache_size: int = 8,
    ):
        if isinstance(model, FittedModel):
            self.model = model
            self.estimator = None
        else:  # legacy path: a fitted MultiHDBSCAN estimator
            if getattr(model, "_model", None) is None:
                raise RuntimeError(
                    "ClusterServeEngine needs a FittedModel or a fitted "
                    "estimator; call fit(X) first (or use "
                    "ClusterServeEngine.fit / ClusterServeEngine.load)"
                )
            self.model = model.model_
            self.estimator = model
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; got {max_batch}")
        if hierarchy_cache_size < 1:
            raise ValueError(
                f"hierarchy_cache_size must be >= 1; got {hierarchy_cache_size}"
            )
        self.max_batch = max_batch
        self.max_delay_ms = max_delay_ms
        self.model.max_cached_hierarchies = hierarchy_cache_size
        if self.estimator is not None:
            self.estimator._max_cached_hierarchies = hierarchy_cache_size

        self._queue: collections.deque[_Pending] = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._latencies: collections.deque[float] = collections.deque(maxlen=8192)
        self._n_requests = 0
        self._n_queries = 0
        self._n_batches = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._worker = threading.Thread(
            target=self._run, name="cluster-serve-worker", daemon=True
        )
        self._worker.start()

    @classmethod
    def fit(cls, X, *, serve_options: dict | None = None, **estimator_options):
        """Fit a fresh estimator and wrap it (the one-call serving path)."""
        from ..api import MultiHDBSCAN

        est = MultiHDBSCAN(**estimator_options).fit(X)
        return cls(est, **(serve_options or {}))

    @classmethod
    def load(
        cls,
        path: str,
        *,
        serve_options: dict | None = None,
        **load_options,
    ) -> "ClusterServeEngine":
        """Boot a serve worker from a saved FittedModel artifact — no refit.

        ``load_options`` forward to :meth:`FittedModel.load` (``backend``,
        ``mesh``, ``plan``, ``policy``, ``expect_config_hash``);
        ``serve_options`` to the engine constructor (``max_batch``,
        ``max_delay_ms``, ``hierarchy_cache_size``).  A loaded engine
        answers predict/labels identically to one wrapping the model that
        produced the artifact.
        """
        model = FittedModel.load(path, **load_options)
        return cls(model, **(serve_options or {}))

    # -- client surface (thread-safe) --------------------------------------

    def submit_predict(
        self,
        Q,
        mpts: int | None = None,
        policy: SelectionPolicy | None = None,
    ) -> Future:
        """Enqueue an out-of-sample batch; resolves to (labels, probs) for
        one mpts, or a PredictResult for the whole range (mpts=None).

        Malformed requests (wrong feature count, NaN coordinates, mpts
        outside the fitted range) are rejected HERE, before enqueueing — a
        bad request must fail alone, never poison the strangers it would
        have been micro-batched with.
        """
        Q = np.asarray(Q)
        if Q.ndim == 1:
            Q = Q[None, :]
        predict.validate_queries(Q, self.model.n_features)
        if mpts is not None:
            self.model.row_of(mpts)  # KeyError early
        return self._submit(
            _Pending("predict", Future(), time.monotonic(), q=Q, mpts=mpts,
                     policy=policy)
        )

    def predict(
        self,
        Q,
        mpts: int | None = None,
        policy: SelectionPolicy | None = None,
        timeout: float | None = 60.0,
    ):
        """Blocking ``submit_predict`` (still rides shared micro-batches)."""
        return self.submit_predict(Q, mpts, policy).result(timeout=timeout)

    def labels(
        self,
        mpts: int,
        *,
        policy: SelectionPolicy | None = None,
        cluster_selection_method: str | None = None,
        allow_single_cluster: bool | None = None,
        timeout: float | None = 60.0,
    ) -> np.ndarray:
        """Fitted labels at one level; selection is per-request.

        Pass a :class:`SelectionPolicy` for the full surface (method,
        epsilon, min_cluster_size); the two legacy keyword knobs remain as
        sugar over ``model.default_policy.replace(...)``.
        """
        policy = self._legacy_policy(
            policy, cluster_selection_method, allow_single_cluster
        )
        p = _Pending("labels", Future(), time.monotonic(), mpts=mpts, policy=policy)
        return self._submit(p).result(timeout=timeout)

    def membership(
        self,
        mpts: int,
        policy: SelectionPolicy | None = None,
        timeout: float | None = 60.0,
    ):
        """The full Clustering view at one level: labels + probabilities +
        lambdas + exemplars."""
        p = _Pending("membership", Future(), time.monotonic(), mpts=mpts,
                     policy=policy)
        return self._submit(p).result(timeout=timeout)

    def profile(self, timeout: float | None = 60.0) -> list[dict]:
        return self._submit(
            _Pending("profile", Future(), time.monotonic())
        ).result(timeout=timeout)

    def dbcv_profile(self, timeout: float | None = 60.0) -> list[dict]:
        return self._submit(
            _Pending("dbcv", Future(), time.monotonic())
        ).result(timeout=timeout)

    def _legacy_policy(
        self,
        policy: SelectionPolicy | None,
        cluster_selection_method: str | None,
        allow_single_cluster: bool | None,
    ) -> SelectionPolicy | None:
        if cluster_selection_method is None and allow_single_cluster is None:
            return policy
        if policy is not None:
            raise ValueError(
                "pass either policy= or the legacy cluster_selection_method/"
                "allow_single_cluster knobs, not both"
            )
        base = self.model.default_policy
        changes: dict = {}
        if cluster_selection_method is not None:
            changes["method"] = cluster_selection_method
        if allow_single_cluster is not None:
            changes["allow_single_cluster"] = allow_single_cluster
        return base.replace(**changes)

    def stats(self) -> dict:
        """Latency/throughput counters over the engine's lifetime so far."""
        with self._cv:
            lat = sorted(self._latencies)
            n_req, n_q, n_b = self._n_requests, self._n_queries, self._n_batches
            t0, t1 = self._t_first, self._t_last
        pct = lambda p: float(lat[min(len(lat) - 1, int(p * len(lat)))]) if lat else 0.0  # noqa: E731
        wall = (t1 - t0) if (t0 is not None and t1 is not None and t1 > t0) else 0.0
        return {
            "n_requests": n_req,
            "n_queries": n_q,
            "n_batches": n_b,
            "p50_ms": round(pct(0.50) * 1e3, 3),
            "p95_ms": round(pct(0.95) * 1e3, 3),
            "queries_per_s": round(n_q / wall, 1) if wall > 0 else 0.0,
            "mean_batch": round(n_q / max(n_b, 1), 2),
        }

    def reset_stats(self) -> None:
        """Zero the latency/throughput counters (e.g. after warmup)."""
        with self._cv:
            self._latencies.clear()
            self._n_requests = self._n_queries = self._n_batches = 0
            self._t_first = self._t_last = None

    def close(self) -> None:
        """Drain nothing, reject everything pending, stop the worker."""
        with self._cv:
            self._closed = True
            pending = list(self._queue)
            self._queue.clear()
            self._cv.notify_all()
        for p in pending:
            p.future.set_exception(RuntimeError("ClusterServeEngine closed"))
        self._worker.join(timeout=10.0)

    def __enter__(self) -> "ClusterServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker ------------------------------------------------------------

    def _submit(self, p: _Pending):
        with self._cv:
            if self._closed:
                raise RuntimeError("ClusterServeEngine is closed")
            self._queue.append(p)
            self._cv.notify_all()
        return p.future

    def _take_batch(self) -> list[_Pending]:
        """Pop the next unit of work: one non-predict request, or a micro-
        batch of predict requests (first-come, held ``max_delay_ms`` for
        riders, capped at ``max_batch`` total query rows)."""
        with self._cv:
            while not self._queue and not self._closed:
                self._cv.wait(timeout=0.1)
            if self._closed:
                return []
            head = self._queue.popleft()
            if head.kind != "predict":
                return [head]
            batch = [head]
            rows = len(head.q)
            deadline = time.monotonic() + self.max_delay_ms / 1e3
            while rows < self.max_batch:
                if not self._queue:
                    remain = deadline - time.monotonic()
                    if remain <= 0:
                        break
                    self._cv.wait(timeout=remain)
                    if self._closed:
                        break
                    continue
                if self._queue[0].kind != "predict":
                    break  # preserve FIFO fairness for non-predict work
                nxt = self._queue[0]
                if rows + len(nxt.q) > self.max_batch and rows > 0:
                    break
                self._queue.popleft()
                batch.append(nxt)
                rows += len(nxt.q)
            return batch

    def _run(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                return
            try:
                if batch[0].kind == "predict":
                    self._serve_predict(batch)
                else:
                    self._serve_one(batch[0])
            except Exception as e:  # noqa: BLE001 - failures belong to callers
                for p in batch:
                    if not p.future.done():
                        p.future.set_exception(e)

    def _serve_predict(self, batch: list[_Pending]) -> None:
        """One fused device pass per *policy group* of the micro-batch.

        The attach stage is policy-independent, but the host tree walk is
        not, so riders are grouped by their (resolved) selection policy —
        the overwhelmingly common single-policy batch stays one pass.
        """
        model = self.model
        groups: dict[SelectionPolicy, list[_Pending]] = {}
        for p in batch:
            pol = p.policy if p.policy is not None else model.default_policy
            groups.setdefault(pol, []).append(p)
        for pol, group in groups.items():
            # one device pass for every rider: union of requested levels
            # (any full-range request widens it to the whole fitted range)
            if any(p.mpts is None for p in group):
                mpts_values: Sequence[int] = list(model.msts.mpts_values)
            else:
                mpts_values = sorted({p.mpts for p in group})
            Q = np.concatenate([p.q for p in group], axis=0)
            res = model.predict_range(Q, mpts_values=list(mpts_values), policy=pol)
            t_done = time.monotonic()
            start = 0
            for p in group:
                stop = start + len(p.q)
                if p.mpts is None:
                    out = predict.PredictResult(
                        mpts_values=list(res.mpts_values),
                        labels=res.labels[:, start:stop],
                        probabilities=res.probabilities[:, start:stop],
                        lambdas=res.lambdas[:, start:stop],
                        neighbors=res.neighbors[:, start:stop],
                    )
                else:
                    r = res.mpts_values.index(p.mpts)
                    out = (res.labels[r, start:stop], res.probabilities[r, start:stop])
                p.future.set_result(out)
                start = stop
            # account per group, each with its OWN completion time: a rider's
            # recorded latency must not include other groups' device passes,
            # and a later group's failure must not erase served riders
            self._account(group, t_done, n_queries=len(Q), n_batches=1)

    def _serve_one(self, p: _Pending) -> None:
        model = self.model
        if p.kind == "labels":
            out = model.select(p.mpts, p.policy).labels
        elif p.kind == "membership":
            out = model.select(p.mpts, p.policy)
        elif p.kind == "profile":
            out = model.mpts_profile()
        elif p.kind == "dbcv":
            out = model.dbcv_profile()
        else:  # pragma: no cover - _Pending kinds are internal
            raise ValueError(f"unknown request kind {p.kind!r}")
        p.future.set_result(out)
        self._account([p], time.monotonic(), n_queries=0, n_batches=0)

    def _account(
        self, batch: list[_Pending], t_done: float, *, n_queries: int, n_batches: int
    ) -> None:
        with self._cv:
            for p in batch:
                self._latencies.append(t_done - p.t_submit)
            self._n_requests += len(batch)
            self._n_queries += n_queries
            self._n_batches += n_batches
            if self._t_first is None:
                self._t_first = batch[0].t_submit
            self._t_last = t_done
