"""Oracle-pinned correctness harness for the dual-tree Borůvka tier (ISSUE 6).

The small-n WSPD/SBCN candidate path is the ORACLE: ``candidate_method=
"dualtree"`` must reproduce its results bit-for-bit — same kNN arrays, same
sorted MST weight multisets, same labels for every mpts — on every dataset
family and backend tested.  The dual-tree tier earns this by construction:
its host f64 traversals only select candidate STRUCTURE, while every
distance that reaches results comes from the same device programs as the
oracle path (``_refine_knn`` for kNN, the ``mrd`` programs for weights, the
shared Borůvka/linkage/extraction stages downstream).

One deliberate asymmetry is pinned rather than papered over: on
adversarially duplicate-heavy data the ORACLE kNN kernel's device prefilter
(matmul-form distances + bounded refine slack) can truncate a massively
tied kth boundary, while the dual-tree search returns the exact f32
``(d2, idx)`` top-k — see ``test_knn_exact_on_duplicate_ties``.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro import engine
from repro.core import multi

KMAX = 8


# ---------------------------------------------------------------------------
# dataset families (generators, so every n in the matrix is available)
# ---------------------------------------------------------------------------


def _blobs(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    c = rng.normal(0, 1, (5, 2)) * 6
    per = [n // 5] * 4 + [n - 4 * (n // 5)]
    return np.concatenate(
        [rng.normal(c[i], 0.7, (per[i], 2)) for i in range(5)]
    ).astype(np.float32)


def _moons(n: int, seed: int = 3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    h = n // 2
    t1 = np.linspace(0, np.pi, h)
    t2 = np.linspace(0, np.pi, n - h)
    pts = np.concatenate([
        np.stack([np.cos(t1), np.sin(t1)], axis=1),
        np.stack([1 - np.cos(t2), 0.5 - np.sin(t2)], axis=1),
    ])
    return (pts + rng.normal(0, 0.07, pts.shape)).astype(np.float32)


def _aniso(n: int, seed: int = 5) -> np.ndarray:
    rng = np.random.default_rng(seed)
    shear = np.array([[0.6, -0.6], [-0.4, 0.8]])
    return (rng.normal(0, 1, (n, 2)) @ shear).astype(np.float32)


DATASETS = {"blobs": _blobs, "moons": _moons, "aniso": _aniso}


def _dualtree_plan(plan: engine.Plan) -> engine.Plan:
    return dataclasses.replace(plan, candidate_method="dualtree")


def _assert_bit_identical(x: np.ndarray, backend: str) -> None:
    """Full-pipeline parity: kNN, MST weight multisets, labels for all mpts."""
    plan = engine.resolve_plan("auto", backend=backend)
    oracle = multi.fit_msts(x, KMAX, plan=plan)
    dt = multi.fit_msts(x, KMAX, plan=_dualtree_plan(plan))

    assert oracle.graph.stats.get("path") != "dualtree"
    assert dt.graph.stats.get("path") == "dualtree"

    assert_array_equal(np.asarray(oracle.knn_idx), np.asarray(dt.knn_idx))
    assert_array_equal(np.asarray(oracle.knn_d2), np.asarray(dt.knn_d2))

    # the MST weight MULTISET is unique per weight function, so bit-equality
    # of the sorted rows is the exactness statement (edge CHOICE may differ
    # at exact-tie weights without being wrong)
    assert_array_equal(
        np.sort(np.asarray(oracle.mst_w), axis=1),
        np.sort(np.asarray(dt.mst_w), axis=1),
    )

    h_o, _ = multi.extract_hierarchies(oracle)
    h_d, _ = multi.extract_hierarchies(dt)
    assert len(h_o) == len(h_d) == KMAX - 1
    for a, b in zip(h_o, h_d):
        assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))


# ---------------------------------------------------------------------------
# the parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dataset", sorted(DATASETS))
@pytest.mark.parametrize("backend", ["ref", "jnp", "pallas_interpret"])
def test_oracle_parity_small(dataset, backend):
    """n=200: full dataset x backend matrix (slot AND fused oracle paths)."""
    _assert_bit_identical(DATASETS[dataset](200), backend)


@pytest.mark.parametrize("dataset", sorted(DATASETS))
@pytest.mark.parametrize(
    "backend",
    # ref at mid size duplicates coverage both axes already have (ref at
    # n=200, mid size under jnp) — keep it, but in the slow lane
    ["jnp", pytest.param("ref", marks=pytest.mark.slow)],
)
def test_oracle_parity_mid(dataset, backend):
    """n=1000: every dataset family against both oracle paths."""
    _assert_bit_identical(DATASETS[dataset](1000), backend)


def test_oracle_parity_n4000():
    """n=4000 — above the old routine-benchmark ceiling — stays bit-exact."""
    _assert_bit_identical(_blobs(4000), "jnp")


@pytest.mark.slow
@pytest.mark.parametrize("dataset", ["moons", "aniso"])
def test_oracle_parity_n4000_slow(dataset):
    _assert_bit_identical(DATASETS[dataset](4000), "jnp")


# ---------------------------------------------------------------------------
# contract details: ledger, tier dispatch, exact-kNN guarantee
# ---------------------------------------------------------------------------


def test_dualtree_ledger_tags():
    """One-host-sync-per-stage contract: the dual-tree path materializes
    exactly knn -> graph -> mst (no candidate sizing syncs — the candidate
    count is host knowledge by construction)."""
    x = _blobs(400)
    plan = _dualtree_plan(engine.resolve_plan("auto"))
    with engine.transfer_ledger() as led:
        msts = multi.fit_msts(x, KMAX, plan=plan)
    assert engine.io.tags(led) == ["knn", "graph", "mst"]
    assert msts.graph.stats.get("path") == "dualtree"
    assert msts.mst_ea.shape == (KMAX - 1, len(x) - 1)


def test_auto_tier_dispatch():
    plan = engine.resolve_plan("auto")
    assert not plan.use_dualtree(plan.dualtree_min_n - 1)
    assert plan.use_dualtree(plan.dualtree_min_n)
    assert dataclasses.replace(plan, candidate_method="dualtree").use_dualtree(10)
    assert not dataclasses.replace(plan, candidate_method="wspd").use_dualtree(10**6)
    with pytest.raises(ValueError, match="candidate_method"):
        dataclasses.replace(plan, candidate_method="typo").use_dualtree(100)


def test_auto_tier_switches_at_threshold():
    """A lowered dualtree_min_n flips the auto path over, bit-identically."""
    x = _blobs(300)
    plan = engine.resolve_plan("auto")
    auto_low = dataclasses.replace(plan, dualtree_min_n=100)
    m_wspd = multi.fit_msts(x, KMAX, plan=plan)
    m_auto = multi.fit_msts(x, KMAX, plan=auto_low)
    assert m_wspd.graph.stats.get("path") != "dualtree"
    assert m_auto.graph.stats.get("path") == "dualtree"
    assert_array_equal(
        np.sort(np.asarray(m_wspd.mst_w), axis=1),
        np.sort(np.asarray(m_auto.mst_w), axis=1),
    )


def test_knn_exact_on_duplicate_ties():
    """On duplicate-heavy data the dual-tree kNN equals the exact brute-force
    f32 (d2, idx) top-k — STRONGER than the oracle kernel, whose device
    prefilter can truncate a saturated tie class at the kth boundary."""
    rng = np.random.default_rng(0)
    x = np.stack(
        [np.sort(rng.choice(np.linspace(0, 10, 80), 500)), np.zeros(500)],
        axis=1,
    ).astype(np.float32)
    plan = _dualtree_plan(engine.resolve_plan("auto"))
    k_top = 4
    d2_dt, idx_dt = plan.knn(np.asarray(x), k_top)
    d2_dt, idx_dt = np.asarray(d2_dt), np.asarray(idx_dt)

    n = len(x)
    diff = x[:, None, :] - x[None, :, :]
    d2 = (diff * diff).sum(-1).astype(np.float32)
    np.fill_diagonal(d2, np.inf)
    order = np.lexsort(
        (np.broadcast_to(np.arange(n), (n, n)), d2), axis=1
    )[:, :k_top]
    assert_array_equal(idx_dt, order.astype(idx_dt.dtype))
    assert_array_equal(d2_dt, np.take_along_axis(d2, order, axis=1))


@pytest.mark.slow
def test_candidate_stage_scaling_slope():
    """n-scaling regression guard: the dual-tree candidate stage (kNN +
    candidate-graph build) must scale sub-quadratically.  Fitted log-log
    slope over a 16x size range; the all-pairs-flavored path it replaced
    sits near 2.0, the traversal should hold well under 1.6."""
    from benchmarks import run as bench_run

    ns = bench_run.nscale_sweep(sizes=(2000, 8000, 32000), d=8, kmax=16)
    slope = ns["slope_candidates"]
    assert slope == slope, f"slope fit degenerate: {ns['rows']}"
    assert slope < 1.6, f"candidate-stage slope {slope} (rows: {ns['rows']})"
