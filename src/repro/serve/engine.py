"""Minimal batched serving engine: prefill -> decode loop with sampling.

Production posture without production scope: a fixed-batch continuous loop
(join at prefill boundaries), greedy/temperature sampling, EOS early-exit
mask, and jitted step functions shared across requests.  Used by
examples/serve_lm.py and the serve smoke tests.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0    # 0 => greedy
    eos_id: int = 1


class Engine:
    def __init__(self, cfg, params, max_len: int = 512, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.max_len = max_len
        self.cache_dtype = cache_dtype

        def _prefill(params, tokens):
            return self.model.prefill(
                params, cfg, tokens, max_len=max_len, cache_dtype=cache_dtype
            )

        def _decode(params, cache, cur, key, temperature):
            logits, cache = self.model.decode_step(params, cfg, cache, cur)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(key, logits / jnp.maximum(temperature, 1e-6))
            nxt = jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
            return nxt[:, None], cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def generate(self, requests: list[GenRequest], seed: int = 0) -> list[np.ndarray]:
        """Batched generation; prompts are right-aligned padded to equal len."""
        cfg = self.cfg
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with BOS=0
        max_new = max(r.max_new_tokens for r in requests)
        temp = float(requests[0].temperature)

        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        outs = [np.asarray(nxt)]
        key = jax.random.PRNGKey(seed)
        done = np.zeros(b, bool)
        for t in range(max_new - 1):
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, cache, nxt, sub, jnp.float32(temp))
            cur = np.asarray(nxt)
            outs.append(cur)
            done |= (cur[:, 0] == np.array([r.eos_id for r in requests]))
            if done.all():
                break
        dt = time.monotonic() - t0
        gen = np.concatenate(outs, axis=1)
        results = []
        for i, r in enumerate(requests):
            row = gen[i][: r.max_new_tokens]
            eos = np.nonzero(row == r.eos_id)[0]
            results.append(row[: eos[0] + 1] if len(eos) else row)
        self.last_stats = {
            "wall_s": dt,
            "tokens": int(sum(len(r) for r in results)),
            "tok_per_s": float(sum(len(r) for r in results) / max(dt, 1e-9)),
        }
        return results
