"""Symmetric Bichromatic Closest Neighbors over WSPD pairs (paper §IV-E, Fig 4).

For each well-separated pair (A, B), connect a in A and b in B iff b is a's
closest point in B AND a is b's closest point in A, w.r.t. ``mrd_kmax``.  The
union over all pairs is the RNG** supergraph.

Device data-plane: pairs are bucketed by padded (|A|, |B|) size class and each
size tier is ONE jitted device program — a fixed-shape (chunk, amax, bmax)
mrd tile + masked argmin, dispatched over the tier's chunks with the results
kept on device.  ``sbcn_candidates`` returns the whole candidate set as jax
arrays (``lo``/``hi`` endpoint arrays, lexicographically sorted, duplicates
masked out), so the downstream filter cascade can stay device-resident; the
``sbcn_edges`` wrapper is the host-compacted (m, 2) numpy view.

Tie-robustness: ALL tied row/column minima are kept (a superset of the
single-argmin SBCN), which preserves the RNG-superset property under
duplicate mrd values.

Oversized pairs (padded |A|*|B| above the bucket cap) are evaluated with a
row-chunked two-pass min-reduction: peak memory is O(row_chunk * |B|)
regardless of |A|.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_PAIR_ELEM_CAP = 1 << 18  # max padded |A|*|B| handled by the batched path
_TILE_ELEMS = 1 << 22     # elements per tier-program chunk
_ROW_CHUNK = 2048         # row chunk for oversized pairs
_SENTINEL = np.int32(np.iinfo(np.int32).max)  # invalid / duplicate slot marker

_EPS = 64.0 * 1.1920929e-07


def _mutual_mask(x, cd2k, a_idx, b_idx):
    """SBCN mask for one padded bucket chunk.

    a_idx: (P, amax) int32 point ids padded with -1; likewise b_idx.
    Returns (P, amax, bmax) bool mask of SBCN edges.
    """
    xa = x[a_idx]                                  # (P, amax, d)
    xb = x[b_idx]
    an = jnp.sum(xa.astype(jnp.float32) ** 2, -1)
    bn = jnp.sum(xb.astype(jnp.float32) ** 2, -1)
    d2 = (
        an[:, :, None]
        + bn[:, None, :]
        - 2.0 * jnp.einsum("pad,pbd->pab", xa.astype(jnp.float32), xb.astype(jnp.float32))
    )
    d2 = jnp.maximum(d2, 0.0)
    mrd2 = jnp.maximum(jnp.maximum(cd2k[a_idx][:, :, None], cd2k[b_idx][:, None, :]), d2)
    invalid = (a_idx < 0)[:, :, None] | (b_idx < 0)[:, None, :]
    mrd2 = jnp.where(invalid, jnp.inf, mrd2)
    # Norm-scaled tolerance: near-ties (incl. matmul-form cancellation noise)
    # are ALL kept as mutual-nearest candidates — only ever adds edges.
    tol = jnp.float32(_EPS) * (an[:, :, None] + bn[:, None, :])
    row_min = jnp.min(mrd2, axis=2, keepdims=True)     # (P, amax, 1)
    col_min = jnp.min(mrd2, axis=1, keepdims=True)     # (P, 1, bmax)
    return (
        (mrd2 <= row_min + tol)
        & (mrd2 <= col_min + tol)
        & ~invalid
        & jnp.isfinite(mrd2)
    )


@jax.jit
def _sbcn_tier_chunk(x, cd2k, a_idx, b_idx):
    """One fixed-shape tier chunk -> flat (lo, hi) candidate slots.

    This is THE device program for a size tier: compiled once per
    (chunk, amax, bmax) shape, dispatched over the tier's chunks, outputs
    stay on device.  Non-edge slots hold the sentinel.
    """
    mutual = _mutual_mask(x, cd2k, a_idx, b_idx)
    ga = jnp.broadcast_to(a_idx[:, :, None], mutual.shape)
    gb = jnp.broadcast_to(b_idx[:, None, :], mutual.shape)
    lo = jnp.where(mutual, jnp.minimum(ga, gb), _SENTINEL)
    hi = jnp.where(mutual, jnp.maximum(ga, gb), _SENTINEL)
    return lo.reshape(-1), hi.reshape(-1)


@functools.partial(jax.jit, static_argnames=("row_chunk",))
def _sbcn_large(x, cd2k, a_idx, b_idx, *, row_chunk: int = _ROW_CHUNK):
    """Row-chunked SBCN for one oversized pair. a_idx (na,), b_idx (nb,).

    Two passes over row chunks of the (na, nb) mrd tile — pass 1 reduces the
    column minima, pass 2 re-evaluates each chunk against the global minima —
    so peak memory is O(row_chunk * nb) float32, never the full tile.
    Returns the (na, nb) bool mutual mask.
    """
    na, nb = a_idx.shape[0], b_idx.shape[0]
    rc = min(row_chunk, na)
    na_pad = -(-na // rc) * rc
    a_pad = jnp.full((na_pad,), -1, a_idx.dtype).at[:na].set(a_idx)

    xb = x[b_idx].astype(jnp.float32)
    cdb = cd2k[b_idx]
    bnorm = jnp.sum(xb * xb, -1)

    def mrd_chunk(ac):
        xa = x[ac].astype(jnp.float32)
        anorm = jnp.sum(xa * xa, -1)
        d2 = anorm[:, None] + bnorm[None, :] - 2.0 * xa @ xb.T
        m = jnp.maximum(jnp.maximum(cd2k[ac][:, None], cdb[None, :]), jnp.maximum(d2, 0.0))
        m = jnp.where((ac < 0)[:, None], jnp.inf, m)
        tol = jnp.float32(_EPS) * (anorm[:, None] + bnorm[None, :])
        return m, tol

    chunks = a_pad.reshape(-1, rc)

    def pass1(ac):
        m, _ = mrd_chunk(ac)
        return jnp.min(m, axis=0)                      # (nb,) partial col min

    col_min = jnp.min(jax.lax.map(pass1, chunks), axis=0)[None, :]

    def pass2(ac):
        m, tol = mrd_chunk(ac)
        row_min = jnp.min(m, axis=1, keepdims=True)
        return (m <= row_min + tol) & (m <= col_min + tol) & jnp.isfinite(m)

    mask = jax.lax.map(pass2, chunks).reshape(na_pad, nb)
    return mask[:na]


def _dedup_sorted(lo, hi):
    """Lexicographically sort (lo, hi) slots; mask duplicate / sentinel slots.

    Returns (lo, hi, keep): sorted endpoint arrays and a bool mask that is
    True exactly on the first occurrence of each real edge.
    """
    lo, hi = jax.lax.sort((lo, hi), dimension=0, num_keys=2)
    valid = lo != _SENTINEL
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])]
    )
    return lo, hi, valid & first


@jax.jit
def _count_real(lo):
    return jnp.sum(lo != _SENTINEL)


@jax.jit
def _compact_slots(lo, hi, out_lo, out_hi):
    """Scatter the real slots to the front of a (cap,)-sized buffer.

    The tile programs emit mostly-sentinel slot arrays (one slot per tile
    cell); sorting those directly is O(total cells log cells) — compacting
    first makes the dedup sort run on ~m candidates instead.  ``out_lo`` /
    ``out_hi`` are sentinel-filled buffers whose size bounds the real count.
    """
    valid = lo != _SENTINEL
    dst = jnp.where(valid, jnp.cumsum(valid) - 1, out_lo.shape[0])
    return (
        out_lo.at[dst].set(lo, mode="drop"),
        out_hi.at[dst].set(hi, mode="drop"),
    )


def sbcn_candidates(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
    *,
    tile_elems: int = _TILE_ELEMS,
    pair_cap: int = _PAIR_ELEM_CAP,
    row_chunk: int = _ROW_CHUNK,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """All SBCN candidate edges across WSPD pairs, device-resident.

    The (start, len) pair ranges index the fair-split tree's ``perm`` array;
    all bucketing/padding is vectorized numpy control-plane work (no per-pair
    Python, no device sync).  Returns ``(lo, hi, keep)`` jax int32/bool
    arrays: padded candidate slots sorted by (lo, hi) with ``keep`` marking
    the unique real edges — downstream stages mask instead of compacting, so
    nothing crosses back to the host here.
    """
    perm = perm.astype(np.int64)

    # canonicalize |A| <= |B|
    swap = a_len > b_len
    a_start, b_start = np.where(swap, b_start, a_start), np.where(swap, a_start, b_start)
    a_len, b_len = np.where(swap, b_len, a_len), np.where(swap, a_len, b_len)

    los: list[jax.Array] = []
    his: list[jax.Array] = []

    # fast path: singleton-singleton pairs ARE their own SBCN edge
    ss = (a_len == 1) & (b_len == 1)
    if ss.any():
        pa = perm[a_start[ss]].astype(np.int32)
        pb = perm[b_start[ss]].astype(np.int32)
        los.append(jnp.asarray(np.minimum(pa, pb)))
        his.append(jnp.asarray(np.maximum(pa, pb)))

    rest = np.nonzero(~ss)[0]
    if len(rest):
        al, bl = a_len[rest], b_len[rest]
        # quantize pair sizes to pow2 tiers: with |A| <= |B| canonicalized
        # this is ~30 compiled tile programs, and padded tile area stays
        # within ~20% of the intrinsic sum(|A|*|B|) — coarser tiers (e.g.
        # {1,8,64,512}) compile fewer programs but inflate the slot arrays
        # (and every downstream compaction) by ~4x.
        tiers = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256, 512], np.int64)

        def tier_of(v):
            return tiers[np.searchsorted(tiers, np.minimum(v, tiers[-1]))]

        ka = tier_of(al)
        kb = tier_of(bl)
        big = (al > tiers[-1]) | (bl > tiers[-1]) | (ka * kb > pair_cap)

        for key in np.unique(ka[~big] * (1 << 32) + kb[~big]):
            kaa, kbb = int(key >> 32), int(key & ((1 << 32) - 1))
            sel = rest[(ka == kaa) & (kb == kbb) & ~big]
            P = len(sel)
            # vectorized padded gather of pair point-sets
            ar = a_start[sel][:, None] + np.arange(kaa)[None, :]
            av = (np.arange(kaa)[None, :] < a_len[sel][:, None])
            a_pad = np.where(av, perm[np.minimum(ar, len(perm) - 1)], -1).astype(np.int32)
            br = b_start[sel][:, None] + np.arange(kbb)[None, :]
            bv = (np.arange(kbb)[None, :] < b_len[sel][:, None])
            b_pad = np.where(bv, perm[np.minimum(br, len(perm) - 1)], -1).astype(np.int32)

            # chunk shape: bounded by the tile budget AND by the tier's actual
            # pair count rounded to a power of two — padding a small tier up
            # to the full tile budget would burn orders of magnitude more
            # compute than the real pairs.  Pow2 rounding keeps the jit cache
            # at ~1 program per tier per dataset scale.
            chunk = max(1, min(tile_elems // (kaa * kbb), 1 << (P - 1).bit_length()))
            P_pad = -(-P // chunk) * chunk
            if P_pad != P:
                a_pad = np.concatenate([a_pad, np.full((P_pad - P, kaa), -1, np.int32)])
                b_pad = np.concatenate([b_pad, np.full((P_pad - P, kbb), -1, np.int32)])
            for c0 in range(0, P_pad, chunk):
                lo_c, hi_c = _sbcn_tier_chunk(
                    x,
                    cd2_kmax,
                    jnp.asarray(a_pad[c0 : c0 + chunk]),
                    jnp.asarray(b_pad[c0 : c0 + chunk]),
                )
                los.append(lo_c)
                his.append(hi_c)

        for gi in np.nonzero(big)[0]:
            sel = rest[gi]
            a = perm[a_start[sel] : a_start[sel] + a_len[sel]].astype(np.int32)
            b = perm[b_start[sel] : b_start[sel] + b_len[sel]].astype(np.int32)
            aj, bj = jnp.asarray(a), jnp.asarray(b)
            mutual = _sbcn_large(x, cd2_kmax, aj, bj, row_chunk=row_chunk)
            ga = jnp.broadcast_to(aj[:, None], mutual.shape)
            gb = jnp.broadcast_to(bj[None, :], mutual.shape)
            los.append(jnp.where(mutual, jnp.minimum(ga, gb), _SENTINEL).reshape(-1))
            his.append(jnp.where(mutual, jnp.maximum(ga, gb), _SENTINEL).reshape(-1))

    if not los:
        z = jnp.zeros((0,), jnp.int32)
        return z, z, jnp.zeros((0,), bool)
    lo_all = jnp.concatenate(los)
    hi_all = jnp.concatenate(his)
    # ONE scalar sync sizes the compaction buffer (the only host round-trip
    # in candidate generation); everything else stays device-resident.
    from .. import engine

    n_real = int(engine.to_host(_count_real(lo_all), "candidate_slots"))
    if n_real == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z, jnp.zeros((0,), bool)
    cap = -(-n_real // 4096) * 4096  # quantized: reuses the sort/dedup programs
    buf = jnp.full((cap,), _SENTINEL, jnp.int32)
    lo_c, hi_c = _compact_slots(lo_all, hi_all, buf, buf)
    return _dedup_sorted(lo_c, hi_c)


# ---------------------------------------------------------------------------
# Fused-cascade emission: bounded per-row candidate keys (PR 3)
# ---------------------------------------------------------------------------
#
# The slot-array path above emits one slot per TILE CELL (|A|x|B| per pair —
# ~8M slots for ~1M candidates at n=4000) and pays for it downstream: a
# 2-array scatter compaction over every cell plus a variadic 2-key dedup
# sort.  The cascade path emits at most ``tie_cap`` packed int32 keys per
# (pair, A-row) — an SBCN edge must be its row's minimum, so ``tie_cap``
# bounds real emissions except under mass ties — and detects the tie
# overflow EXACTLY so the caller can fall back to the dense slot path
# (semantics preserved under heavy duplicates).  Keys pack (lo, hi) as
# ``lo * n + hi``; the single-key sort dedups ~7x faster than the variadic
# sort and doubles as the compaction (sentinels sort to the end).

_SMALL_AMAX = 4          # bucketed-tier path bounds (pow2-exact tiers)
_SMALL_BMAX = 8
_TIER_CHUNK_ELEMS = 1 << 17   # fixed cells per tier chunk => shape-stable programs
_ROWPATH_PAIR_BLOCK = 32      # pairs per row-path dispatch (fixed)


def _pack_keys(lo, hi, n_pack, found):
    return jnp.where(found, lo * n_pack + hi, _SENTINEL)


def _emit_from_mask(mask, a_idx, b_idx, n_pack, tie_cap: int):
    """Per-row top-``tie_cap`` emission from an SBCN mutual mask.

    mask (P, A, B) bool; a_idx (P, A) / b_idx (P, B) int32 point ids (-1 pad).
    Returns (keys (P*A*tie_cap,), counters (2,)): packed candidate keys and
    [n_mutual_slots, n_rows_overflowing].  Selection keeps the first
    ``tie_cap`` set columns per row — identical to the dense mask whenever no
    row has more than ``tie_cap`` tied minima (counters[1] reports exactly
    when that fails, so callers can fall back without losing edges).
    """
    P, A, B = mask.shape
    if B <= max(tie_cap, 4):
        # narrow tiers: dense emission (every cell is a slot) costs at most
        # one extra slot per row and skips the selection passes entirely;
        # overflow is impossible because nothing is dropped
        lo = jnp.minimum(a_idx[:, :, None], b_idx[:, None, :])
        hi = jnp.maximum(a_idx[:, :, None], b_idx[:, None, :])
        keys = _pack_keys(lo, hi, n_pack, mask)
        counters = jnp.stack([jnp.sum(mask), jnp.int32(0)]).astype(jnp.int32)
        return keys.reshape(P * A * B), counters
    iota_b = jnp.arange(B, dtype=jnp.int32)
    m = mask
    keys = []
    for _ in range(min(tie_cap, B)):  # a row has at most B set columns
        j = jnp.argmax(m, axis=2)                                 # (P, A)
        found = jnp.take_along_axis(m, j[..., None], axis=2)[..., 0]
        gb = jnp.take_along_axis(b_idx, j, axis=1)                # (P, A)
        lo = jnp.minimum(a_idx, gb)
        hi = jnp.maximum(a_idx, gb)
        keys.append(_pack_keys(lo, hi, n_pack, found))
        m = m & (iota_b[None, None, :] != j[..., None])
    counts = jnp.sum(mask, axis=2)
    counters = jnp.stack(
        [jnp.sum(counts), jnp.sum(counts > tie_cap)]
    ).astype(jnp.int32)
    return jnp.stack(keys, axis=-1).reshape(P * A * len(keys)), counters


@functools.partial(jax.jit, static_argnames=("tie_cap",))
def _tier_emit(x, cd2k, a_idx, b_idx, n_pack, *, tie_cap: int):
    """One fixed-shape bucketed-tier chunk -> bounded packed keys + counters."""
    mutual = _mutual_mask(x, cd2k, a_idx, b_idx)
    return _emit_from_mask(mutual, a_idx, b_idx, n_pack, tie_cap)


@functools.partial(jax.jit, static_argnames=("tie_cap",))
def _rowpath_emit(x, cd2k, a_chunks, b_idx, n_pack, *, tie_cap: int):
    """Row-chunked SBCN emission for a block of same-shape oversized pairs.

    a_chunks (Pb, nc, rc) int32 padded -1; b_idx (Pb, nb) padded -1.  Same
    two-pass min-reduction as ``_sbcn_large`` (bit-identical mrd tiles and
    tie tolerance), but emits bounded per-row keys instead of the dense
    (na, nb) mask.  Peak memory is O(rc * nb) per pair regardless of na.
    """
    eps = jnp.float32(_EPS)

    def one_pair(args):
        ac_all, bj = args                                # (nc, rc), (nb,)
        xb = x[bj].astype(jnp.float32)
        cdb = cd2k[bj]
        bnorm = jnp.sum(xb * xb, -1)
        b_bad = bj < 0

        def mrd_chunk(ac):
            xa = x[ac].astype(jnp.float32)
            anorm = jnp.sum(xa * xa, -1)
            d2 = anorm[:, None] + bnorm[None, :] - 2.0 * xa @ xb.T
            m = jnp.maximum(
                jnp.maximum(cd2k[ac][:, None], cdb[None, :]), jnp.maximum(d2, 0.0)
            )
            m = jnp.where((ac < 0)[:, None] | b_bad[None, :], jnp.inf, m)
            tol = eps * (anorm[:, None] + bnorm[None, :])
            return m, tol

        def emit(m, tol, col_min, ac):
            row_min = jnp.min(m, axis=1, keepdims=True)
            mask = (m <= row_min + tol) & (m <= col_min + tol) & jnp.isfinite(m)
            return _emit_from_mask(mask[None], ac[None], bj[None], n_pack, tie_cap)

        if ac_all.shape[0] == 1:
            # single row chunk: the tile IS the whole pair — one pass
            m, tol = mrd_chunk(ac_all[0])
            return emit(m, tol, jnp.min(m, axis=0, keepdims=True), ac_all[0])

        def pass1(ac):
            return jnp.min(mrd_chunk(ac)[0], axis=0)

        col_min = jnp.min(jax.lax.map(pass1, ac_all), axis=0)[None, :]

        def pass2(ac):
            m, tol = mrd_chunk(ac)
            return emit(m, tol, col_min, ac)

        keys, counters = jax.lax.map(pass2, ac_all)
        return keys.reshape(-1), jnp.sum(counters, axis=0)

    keys, counters = jax.lax.map(one_pair, (a_chunks, b_idx))
    return keys.reshape(-1), jnp.sum(counters, axis=0)


@jax.jit
def _sort_dedup_stats(keys):
    """Sort packed keys (sentinels last); return (sorted, n_real, n_unique)."""
    ks = jnp.sort(keys)
    valid = ks != _SENTINEL
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    return ks, jnp.sum(valid), jnp.sum(valid & first)


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def _pow2_ceil_np(v: np.ndarray) -> np.ndarray:
    """Vectorized pow2 round-up (exact: log2 of small ints is exact in f64)."""
    return np.left_shift(
        np.int64(1),
        np.ceil(np.log2(np.maximum(v, 1))).astype(np.int64),
    )


def cascade_candidates(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
    *,
    tie_cap: int = 2,
    tier_chunk_elems: int = _TIER_CHUNK_ELEMS,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Bounded-emission SBCN candidates as sorted packed keys, device-resident.

    Returns device values ``(keys_sorted, n_real, n_unique, n_mutual,
    n_overflow)``.  ``keys_sorted`` is pow2-padded with sentinels;
    ``n_overflow > 0`` means some (pair, row) had more than ``tie_cap`` tied
    SBCN minima and the caller MUST fall back to ``sbcn_candidates`` (the
    dense slot path) — emission would otherwise drop tied edges.  No host
    sync happens here; the caller materializes the four scalars at its own
    ledger point.

    Requires n <= 46340 (packed ``lo * n + hi`` must fit int32); callers
    gate on that before choosing this path.
    """
    from .. import engine

    perm = perm.astype(np.int32)  # halves the gather traffic below
    swap = a_len > b_len
    a_start, b_start = np.where(swap, b_start, a_start), np.where(swap, a_start, b_start)
    a_len, b_len = np.where(swap, b_len, a_len), np.where(swap, a_len, b_len)

    n_pack = jnp.int32(x.shape[0])
    key_parts: list[jax.Array] = []
    counter_parts: list[jax.Array] = []

    # singleton-singleton pairs ARE their own SBCN edge: emit on the host
    # control plane (pure numpy), zero device compute
    ss = (a_len == 1) & (b_len == 1)
    n_ss = int(ss.sum())
    if n_ss:
        pa = perm[a_start[ss]]
        pb = perm[b_start[ss]]
        ss_keys = (
            np.minimum(pa, pb).astype(np.int64) * int(x.shape[0])
            + np.maximum(pa, pb)
        )
        key_parts.append(jnp.asarray(ss_keys.astype(np.int32)))

    rest = np.nonzero(~ss)[0]
    if len(rest):
        al, bl = a_len[rest], b_len[rest]
        small = (al <= _SMALL_AMAX) & (bl <= _SMALL_BMAX)

        # -- small tiers: pow2-exact (amax, bmax), FIXED chunk per tier ------
        ka = _pow2_ceil_np(al)
        kb = _pow2_ceil_np(bl)
        for key in np.unique(ka[small] * 16 + kb[small]) if small.any() else []:
            kaa, kbb = int(key) // 16, int(key) % 16
            sel = rest[small & (ka == kaa) & (kb == kbb)]
            P = len(sel)
            chunk = max(8, tier_chunk_elems // (kaa * kbb))
            P_pad = -(-P // chunk) * chunk
            a_pad = _padded_gather(perm, a_start[sel], a_len[sel], kaa, P_pad)
            b_pad = _padded_gather(perm, b_start[sel], b_len[sel], kbb, P_pad)
            emit = engine.plan.cached_program(
                ("tier_emit", kaa, kbb, chunk, tie_cap, x.shape[1]),
                lambda: functools.partial(_tier_emit, tie_cap=tie_cap),
            )
            for c0 in range(0, P_pad, chunk):
                keys_c, counters_c = emit(
                    x, cd2_kmax,
                    jnp.asarray(a_pad[c0 : c0 + chunk]),
                    jnp.asarray(b_pad[c0 : c0 + chunk]),
                    n_pack,
                )
                key_parts.append(keys_c)
                counter_parts.append(counters_c)

        # -- row path: everything larger, grouped by padded shape -----------
        rp = rest[~small]
        if len(rp):
            na, nb = a_len[rp], b_len[rp]
            # pow2 ladders (min row chunk 32, min b width 64): a handful of
            # shape-stable programs, padded area within ~2x of intrinsic
            rc = np.minimum(256, np.maximum(32, _pow2_ceil_np(na)))
            nc = _pow2_ceil_np(-(-na // rc))
            nbp = np.maximum(64, _pow2_ceil_np(nb))
            shape_key = rc * (1 << 40) + nc * (1 << 20) + nbp
            for skey in np.unique(shape_key):
                sel = rp[shape_key == skey]
                rcc = int(rc[shape_key == skey][0])
                ncc = int(nc[shape_key == skey][0])
                nbb = int(nbp[shape_key == skey][0])
                # pair block bounded by a cell budget: huge tiles dispatch in
                # small blocks so a lone oversized pair never pays for a full
                # block of padding
                Pb = int(
                    min(_ROWPATH_PAIR_BLOCK, max(2, (1 << 21) // (ncc * rcc * nbb)))
                )
                emit = engine.plan.cached_program(
                    ("rowpath_emit", rcc, ncc, nbb, Pb, tie_cap, x.shape[1]),
                    lambda: functools.partial(_rowpath_emit, tie_cap=tie_cap),
                )
                for g0 in range(0, len(sel), Pb):
                    grp = sel[g0 : g0 + Pb]
                    a_blk = _padded_gather(
                        perm, a_start[grp], a_len[grp], ncc * rcc, Pb
                    ).reshape(Pb, ncc, rcc)
                    b_blk = _padded_gather(perm, b_start[grp], b_len[grp], nbb, Pb)
                    keys_c, counters_c = emit(
                        x, cd2_kmax, jnp.asarray(a_blk), jnp.asarray(b_blk), n_pack
                    )
                    key_parts.append(keys_c)
                    counter_parts.append(counters_c)

    if not key_parts:
        z = jnp.full((8,), _SENTINEL, jnp.int32)
        zero = jnp.int32(0)
        return z, zero, zero, zero, zero

    keys = jnp.concatenate(key_parts)
    # quantize the sort length to coarse blocks: ~1 sort program per scale,
    # <=12.5% padding (a full pow2 round-up can nearly double the sort)
    q = 1 << 18
    total = min(_pow2_ceil(keys.shape[0]), -(-keys.shape[0] // q) * q)
    if total != keys.shape[0]:
        keys = jnp.concatenate(
            [keys, jnp.full((total - keys.shape[0],), _SENTINEL, jnp.int32)]
        )
    keys_sorted, n_real, n_unique = _sort_dedup_stats(keys)
    if counter_parts:
        counters = jnp.sum(jnp.stack(counter_parts), axis=0)
    else:
        counters = jnp.zeros((2,), jnp.int32)
    n_mutual = counters[0] + jnp.int32(n_ss)
    return keys_sorted, n_real, n_unique, n_mutual, counters[1]


def _padded_gather(perm, starts, lens, width: int, rows: int):
    """(rows, width) int32 point-id matrix from (start, len) perm ranges,
    padded with -1 (short ranges AND missing rows)."""
    out = np.full((rows, width), -1, np.int32)
    k = len(starts)
    if k:
        r = starts[:, None] + np.arange(width)[None, :]
        v = np.arange(width)[None, :] < lens[:, None]
        out[:k] = np.where(v, perm[np.minimum(r, len(perm) - 1)], -1)
    return out


def sbcn_edges(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
) -> np.ndarray:
    """Host-compacted SBCN edges: (m, 2) int64, a < b, unique.

    One materialization of the device candidate set (the pipeline proper
    stays on ``sbcn_candidates`` and defers this to the graph compaction).
    """
    from .. import engine

    lo, hi, keep = sbcn_candidates(
        x, cd2_kmax, perm, a_start, a_len, b_start, b_len
    )
    lo, hi, keep = engine.to_host((lo, hi, keep), "candidates")
    return np.stack([lo[keep].astype(np.int64), hi[keep].astype(np.int64)], axis=1)
