"""Benchmark entry point: one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Full-scale sweeps live in
paper_sweeps.py; this entry runs host-sized versions of each (the paper's
headline quantities — speedup ratios and edge-count reductions — are
scale-free).  Roofline rows are appended from the dry-run artifacts when
present (derived = dominant-term milliseconds).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import paper_sweeps

    rows = []
    print("name,us_per_call,derived")

    # Fig 5a/6a: dataset-size sweep
    for r in paper_sweeps.size_sweep(sizes=(1000, 2000, 4000), d=8, kmax=16):
        name = f"fig5a_size/n={r['n']}/{r['method']}"
        edge_red = r["edges_complete"] / max(r["edges"], 1)
        print(f"{name},{r['wall_s'] * 1e6:.0f},edge_reduction={edge_red:.1f}x")
        rows.append(r)

    # Fig 5b/6b: dimensionality sweep
    for r in paper_sweeps.dim_sweep(dims=(2, 8, 32), n=2000, kmax=16):
        name = f"fig5b_dims/d={r['d']}/{r['method']}"
        edge_red = r["edges_complete"] / max(r["edges"], 1)
        print(f"{name},{r['wall_s'] * 1e6:.0f},edge_reduction={edge_red:.1f}x")
        rows.append(r)

    # Fig 5c/6c + Table II + Fig 7: kmax sweep with ratio-vs-one-hierarchy
    for r in paper_sweeps.kmax_sweep(kmaxes=(4, 16, 64), n=2000, d=8):
        name = f"tab2_kmax/k={r['kmax']}/{r['method']}"
        print(f"{name},{r['wall_s'] * 1e6:.0f},ratio_vs_one={r['ratio_vs_one']}")
        rows.append(r)

    # extraction phase: batched device linkage vs legacy per-edge Python loop
    for r in paper_sweeps.extraction_sweep(n=2000, d=8, kmax=16):
        name = f"extract/k={r['kmax']}/{r['method']}"
        print(f"{name},{r['wall_s'] * 1e6:.0f},speedup_vs_loop={r['speedup_vs_loop']}x")
        rows.append(r)

    # roofline rows from dry-run artifacts (if the matrix has been run)
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art):
        from benchmarks import roofline

        recs = roofline.load_records(art)
        for r in recs:
            if r.get("status") != "ok" or r.get("mesh") != "single":
                continue
            t = r["roofline"]
            dom_ms = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"]) * 1e3
            print(
                f"roofline/{r['arch']}/{r['shape']},{r['t_compile_s'] * 1e6:.0f},"
                f"dominant={t['dominant']}:{dom_ms:.1f}ms"
            )

    import json

    out = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench_rows.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
