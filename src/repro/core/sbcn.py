"""Symmetric Bichromatic Closest Neighbors over WSPD pairs (paper §IV-E, Fig 4).

For each well-separated pair (A, B), connect a in A and b in B iff b is a's
closest point in B AND a is b's closest point in A, w.r.t. ``mrd_kmax``.  The
union over all pairs is the RNG** supergraph.

Device data-plane: pairs are bucketed by padded (|A|, |B|) size class and each
bucket is evaluated as one batched (P, amax, bmax) mrd tile + masked argmin —
the same blocked-tile shape the MXU wants.  Tie-robustness: ALL tied
row/column minima are kept (a superset of the single-argmin SBCN), which
preserves the RNG-superset property under duplicate mrd values.

Oversized pairs (|A|*|B| above the bucket cap) are evaluated with a chunked
min-reduction instead of one tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_PAIR_ELEM_CAP = 1 << 18  # max padded |A|*|B| handled by the batched path


@functools.partial(jax.jit, static_argnames=("amax", "bmax"))
def _sbcn_bucket(x, cd2k, a_idx, b_idx, *, amax: int, bmax: int):
    """Batched SBCN for one bucket.

    a_idx: (P, amax) int32 point ids padded with -1; likewise b_idx.
    Returns (P, amax, bmax) bool mask of SBCN edges.
    """
    xa = x[a_idx]                                  # (P, amax, d)
    xb = x[b_idx]
    d2 = (
        jnp.sum(xa.astype(jnp.float32) ** 2, -1)[:, :, None]
        + jnp.sum(xb.astype(jnp.float32) ** 2, -1)[:, None, :]
        - 2.0 * jnp.einsum("pad,pbd->pab", xa.astype(jnp.float32), xb.astype(jnp.float32))
    )
    d2 = jnp.maximum(d2, 0.0)
    mrd2 = jnp.maximum(jnp.maximum(cd2k[a_idx][:, :, None], cd2k[b_idx][:, None, :]), d2)
    invalid = (a_idx < 0)[:, :, None] | (b_idx < 0)[:, None, :]
    mrd2 = jnp.where(invalid, jnp.inf, mrd2)
    # Norm-scaled tolerance: near-ties (incl. matmul-form cancellation noise)
    # are ALL kept as mutual-nearest candidates — only ever adds edges.
    eps = jnp.float32(64.0 * 1.1920929e-07)
    tol = eps * (
        jnp.sum(xa.astype(jnp.float32) ** 2, -1)[:, :, None]
        + jnp.sum(xb.astype(jnp.float32) ** 2, -1)[:, None, :]
    )
    row_min = jnp.min(mrd2, axis=2, keepdims=True)     # (P, amax, 1)
    col_min = jnp.min(mrd2, axis=1, keepdims=True)     # (P, 1, bmax)
    mutual = (
        (mrd2 <= row_min + tol)
        & (mrd2 <= col_min + tol)
        & ~invalid
        & jnp.isfinite(mrd2)
    )
    return mutual


@jax.jit
def _sbcn_large(x, cd2k, a_idx, b_idx):
    """Chunked SBCN for one oversized pair. a_idx (na,), b_idx (nb,)."""
    xa, xb = x[a_idx], x[b_idx]
    cda, cdb = cd2k[a_idx], cd2k[b_idx]

    def mrd_block(xi, cdi, xj, cdj):
        d2 = (
            jnp.sum(xi.astype(jnp.float32) ** 2, -1)[:, None]
            + jnp.sum(xj.astype(jnp.float32) ** 2, -1)[None, :]
            - 2.0 * xi.astype(jnp.float32) @ xj.astype(jnp.float32).T
        )
        return jnp.maximum(jnp.maximum(cdi[:, None], cdj[None, :]), jnp.maximum(d2, 0.0))

    m = mrd_block(xa, cda, xb, cdb)                    # (na, nb) — one shot; caller
    eps = jnp.float32(64.0 * 1.1920929e-07)            # chunks upstream if needed
    tol = eps * (
        jnp.sum(xa.astype(jnp.float32) ** 2, -1)[:, None]
        + jnp.sum(xb.astype(jnp.float32) ** 2, -1)[None, :]
    )
    row_min = jnp.min(m, axis=1, keepdims=True)
    col_min = jnp.min(m, axis=0, keepdims=True)
    return (m <= row_min + tol) & (m <= col_min + tol)


def sbcn_edges(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
) -> np.ndarray:
    """All SBCN edges across WSPD pairs. Returns (m, 2) int64, a < b, unique.

    Pairs are given as (start, len) ranges into the fair-split tree's `perm`
    array; all bucketing/padding is vectorized numpy (no per-pair Python).
    """
    n = x.shape[0]
    perm = perm.astype(np.int64)

    # canonicalize |A| <= |B|
    swap = a_len > b_len
    a_start, b_start = np.where(swap, b_start, a_start), np.where(swap, a_start, b_start)
    a_len, b_len = np.where(swap, b_len, a_len), np.where(swap, a_len, b_len)

    out: list[np.ndarray] = []

    # fast path: singleton-singleton pairs ARE their own SBCN edge
    ss = (a_len == 1) & (b_len == 1)
    if ss.any():
        out.append(
            np.stack([perm[a_start[ss]], perm[b_start[ss]]], axis=1)
        )

    rest = np.nonzero(~ss)[0]
    if len(rest):
        al, bl = a_len[rest], b_len[rest]
        # quantize pair sizes to a few tiers: bounds JIT-shape diversity to
        # ~10 compiled bucket kernels instead of O(log^2 n) pow2 combos.
        tiers = np.array([1, 8, 64, 512], np.int64)

        def tier_of(v):
            return tiers[np.searchsorted(tiers, np.minimum(v, tiers[-1]))]

        ka = tier_of(al)
        kb = tier_of(bl)
        big = (al > tiers[-1]) | (bl > tiers[-1]) | (ka * kb > _PAIR_ELEM_CAP)

        for key in np.unique(ka[~big] * (1 << 32) + kb[~big]):
            kaa, kbb = int(key >> 32), int(key & ((1 << 32) - 1))
            sel = rest[(ka == kaa) & (kb == kbb) & ~big]
            P = len(sel)
            # vectorized padded gather of pair point-sets
            ar = a_start[sel][:, None] + np.arange(kaa)[None, :]
            av = (np.arange(kaa)[None, :] < a_len[sel][:, None])
            a_pad = np.where(av, perm[np.minimum(ar, len(perm) - 1)], -1).astype(np.int32)
            br = b_start[sel][:, None] + np.arange(kbb)[None, :]
            bv = (np.arange(kbb)[None, :] < b_len[sel][:, None])
            b_pad = np.where(bv, perm[np.minimum(br, len(perm) - 1)], -1).astype(np.int32)

            # fixed chunk shape: pad the last chunk so every call per tier
            # hits the same jitted program (compile once per tier, reused
            # across datasets/benchmark sweeps)
            chunk = max(1, (1 << 22) // (kaa * kbb))
            if P % chunk:
                padrows = chunk - (P % chunk) if P > chunk else chunk - P
                a_pad = np.concatenate(
                    [a_pad, np.full((padrows, kaa), -1, np.int32)]
                )
                b_pad = np.concatenate(
                    [b_pad, np.full((padrows, kbb), -1, np.int32)]
                )
            for c0 in range(0, P, chunk):
                ap = jnp.asarray(a_pad[c0 : c0 + chunk])
                bp = jnp.asarray(b_pad[c0 : c0 + chunk])
                mutual = np.asarray(
                    _sbcn_bucket(x, cd2_kmax, ap, bp, amax=kaa, bmax=kbb)
                )
                p, i, j = np.nonzero(mutual)
                out.append(
                    np.stack(
                        [
                            a_pad[c0 + p, i].astype(np.int64),
                            b_pad[c0 + p, j].astype(np.int64),
                        ],
                        axis=1,
                    )
                )

        for gi in np.nonzero(big)[0]:
            sel = rest[gi]
            a = perm[a_start[sel] : a_start[sel] + a_len[sel]]
            b = perm[b_start[sel] : b_start[sel] + b_len[sel]]
            mutual = np.asarray(
                _sbcn_large(x, cd2_kmax, jnp.asarray(a), jnp.asarray(b))
            )
            i, j = np.nonzero(mutual)
            out.append(np.stack([a[i], b[j]], axis=1))

    if not out:
        return np.zeros((0, 2), np.int64)
    edges = np.concatenate(out, axis=0)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    packed = np.unique(lo * np.int64(n) + hi)
    return np.stack([packed // n, packed % n], axis=1)
