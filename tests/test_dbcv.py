"""DBCV relative validity: degenerate-regime units + loop-reference parity.

Regression context: the guard for the missing-crossing-edge case used to be
``dspc is np.inf`` — a float *identity* check, False for any computed inf —
so those clusters fell through to the generic formula (inf/inf -> nan).
"""

import numpy as np
import pytest

from repro.core.dbcv import dbcv_relative_validity


def test_single_cluster_is_degenerate():
    labels = np.array([0, 0, 0, 0])
    ea, eb = np.array([0, 1, 2]), np.array([1, 2, 3])
    w = np.array([1.0, 1.0, 1.0])
    assert dbcv_relative_validity(ea, eb, w, labels) == -1.0


def test_all_noise_is_degenerate():
    labels = np.array([-1, -1, -1])
    ea, eb = np.array([0, 1]), np.array([1, 2])
    w = np.array([1.0, 1.0])
    assert dbcv_relative_validity(ea, eb, w, labels) == -1.0


def test_two_well_separated_clusters_score_high():
    labels = np.array([0, 0, 0, 1, 1, 1])
    ea = np.array([0, 1, 2, 3, 4])
    eb = np.array([1, 2, 3, 4, 5])
    w = np.array([0.1, 0.1, 10.0, 0.1, 0.1])  # tight clusters, wide bridge
    score = dbcv_relative_validity(ea, eb, w, labels)
    assert score == pytest.approx((10.0 - 0.1) / 10.0)


def test_no_crossing_edges_means_perfect_separation():
    """Clusters connected only THROUGH noise points have no crossing MST
    edge at all: separation is unbounded, V = +1 for both."""
    labels = np.array([0, 0, 1, 1, -1])
    ea = np.array([0, 2, 1, 4])
    eb = np.array([1, 3, 4, 2])
    w = np.array([0.1, 0.1, 5.0, 5.0])  # cluster-noise edges are not crossing
    assert dbcv_relative_validity(ea, eb, w, labels) == pytest.approx(1.0)


def test_computed_inf_crossing_edge_hits_the_separated_branch():
    """The regression proper: an inf WEIGHT flowing through min() produces a
    computed inf that the old identity check missed (nan score)."""
    labels = np.array([0, 0, 1, 1])
    ea = np.array([0, 2, 1])
    eb = np.array([1, 3, 2])
    w = np.array([0.1, 0.1, np.inf])
    score = dbcv_relative_validity(ea, eb, w, labels)
    assert np.isfinite(score)
    assert score == pytest.approx(1.0)


def test_inf_internal_edge_scores_minus_one():
    labels = np.array([0, 0, 1, 1])
    ea = np.array([0, 2, 1])
    eb = np.array([1, 3, 2])
    w = np.array([np.inf, 0.1, 1.0])  # cluster 0 unboundedly sparse
    score = dbcv_relative_validity(ea, eb, w, labels)
    # cluster 0: V = -1; cluster 1: (1.0 - 0.1) / 1.0 = 0.9; equal sizes
    assert score == pytest.approx(0.5 * (-1.0) + 0.5 * 0.9)


def test_zero_weight_edges_give_zero_contrast():
    """Duplicate-point regime: internal and crossing edges all at weight 0
    -> no density contrast in either direction, V = 0 (not nan, not 1)."""
    labels = np.array([0, 0, 1, 1])
    ea = np.array([0, 2, 1])
    eb = np.array([1, 3, 2])
    w = np.zeros(3)
    assert dbcv_relative_validity(ea, eb, w, labels) == 0.0


def _dbcv_loop_reference(ea, eb, w, labels):
    """Per-cluster loop transliteration of the documented cases."""
    cl = np.unique(labels[labels >= 0])
    if len(cl) < 2:
        return -1.0
    n_clustered = int(np.sum(labels >= 0))
    la, lb = labels[ea], labels[eb]
    internal = (la == lb) & (la >= 0)
    crossing = (la != lb) & (la >= 0) & (lb >= 0)
    score = 0.0
    for c in cl:
        mi = internal & (la == c)
        dsc = float(w[mi].max()) if mi.any() else 0.0
        mo = crossing & ((la == c) | (lb == c))
        dspc = float(w[mo].min()) if mo.any() else float("inf")
        if np.isinf(dspc) and np.isinf(dsc):
            v = 0.0
        elif np.isinf(dspc):
            v = 1.0
        elif np.isinf(dsc):
            v = -1.0
        else:
            denom = max(dspc, dsc)
            v = (dspc - dsc) / denom if denom > 0 else 0.0
        score += np.sum(labels == c) / n_clustered * v
    return float(score)


def test_vectorized_matches_loop_reference_on_random_instances():
    rng = np.random.default_rng(7)
    for trial in range(30):
        n = int(rng.integers(6, 40))
        labels = rng.integers(-1, 4, size=n)
        # random spanning-tree-ish edge list
        perm = rng.permutation(n)
        ea = perm[:-1]
        eb = np.array([perm[rng.integers(0, i + 1)] for i in range(n - 1)])
        w = rng.exponential(1.0, size=n - 1)
        if trial % 3 == 0:
            w[rng.integers(0, n - 1)] = np.inf  # exercise the inf branches
        if trial % 4 == 0:
            w[rng.integers(0, n - 1)] = 0.0
        got = dbcv_relative_validity(ea, eb, w, labels)
        want = _dbcv_loop_reference(ea, eb, w, labels)
        assert got == pytest.approx(want), f"trial {trial}"


def test_dbcv_profile_through_estimator(blobs):
    """The estimator range query exercises the fixed branch end-to-end."""
    from repro.api import MultiHDBSCAN

    x, _ = blobs
    est = MultiHDBSCAN(kmax=10).fit(x)
    prof = est.dbcv_profile()
    assert [r["mpts"] for r in prof] == est.mpts_values_
    assert all(np.isfinite(r["dbcv"]) and -1.0 <= r["dbcv"] <= 1.0 for r in prof)
    # mpts=2 shatters the blobs; a mid-range level should beat it
    best = max(prof, key=lambda r: r["dbcv"])
    assert best["dbcv"] >= [r for r in prof if r["mpts"] == 2][0]["dbcv"]
