"""seamless-m4t-large-v2 [audio] — enc-dec 24L+24L d=1024 16H d_ff=8192 vocab=256206.

Transformer backbone only; the audio frontend is a STUB per the task:
input_specs() feeds precomputed fbank-frame embeddings (B, S, 1024) into the
encoder; the decoder is text (dec len = seq/4).  [arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    arch="encdec",
    vocab=256206,
    d_model=1024,
    n_layers=48,                    # 24 enc + 24 dec
    n_enc_layers=24,
    n_dec_layers=24,
    n_heads=16,
    n_kv=16,
    d_head=64,
    d_ff=8192,
    act="gelu",
    mlp_bias=True,
    dec_seq_frac=0.25,
    frontend="frames",
    frontend_dim=1024,
    tie_embeddings=False,
    run_long_500k=False,
    skip_note="enc-dec: a 500k-frame encoder is quadratic; long_500k skipped",
)
