"""Distribution layer: sharding rules + cluster-parallel collectives.

``sharding``         — logical-axis sharding rules (specs -> NamedSharding),
                       activation constraints, and the sharding factories the
                       launcher/dry-run use for params / optimizer / batches.
``cluster_parallel`` — ring collectives for the clustering pipeline (kNN and
                       lune counting over row-sharded point sets).
"""

from . import cluster_parallel, sharding

__all__ = ["cluster_parallel", "sharding"]
