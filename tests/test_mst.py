"""Dense Prim vs scipy oracle.  (Property-based Boruvka checks live in
test_mst_property.py and need hypothesis.)"""

import jax.numpy as jnp
import numpy as np

from repro.core import boruvka, ref as oref


def test_prim_dense_matches_scipy(gauss16d):
    x = gauss16d[:150]
    cd = oref.core_distances(x.astype(np.float64), 6)
    m = oref.mrd_matrix(x.astype(np.float64), 6, cd)
    src, w2 = boruvka.prim_dense_mst(
        jnp.asarray(x), jnp.asarray((cd[:, 5] ** 2).astype(np.float32))
    )
    got = np.sort(np.sqrt(np.asarray(w2)[1:]))
    np.testing.assert_allclose(got, oref.mst_weights(m), rtol=1e-5, atol=1e-6)
