"""Serve clustering queries from one fitted multi-density state.

Fits once, then drives concurrent out-of-sample prediction traffic through
the micro-batching ClusterServeEngine and prints the latency profile.

  PYTHONPATH=src python examples/serve_clusters.py
"""

import sys
import threading

sys.path.insert(0, "src")

import numpy as np

from repro.serve import ClusterServeEngine


def main():
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(500, 2)),
        rng.normal((4, 0), 0.5, size=(500, 2)),
        rng.normal((2, 4), 0.8, size=(300, 2)),
    ]).astype(np.float32)

    with ClusterServeEngine.fit(x, kmax=16) as eng:
        # a burst of concurrent single-query clients, mixed density levels
        queries = x[rng.choice(len(x), size=128)] + rng.normal(0, 0.05, (128, 2)).astype(np.float32)
        results = {}

        def client(i):
            results[i] = eng.predict(queries[i], mpts=int(4 + 4 * (i % 4)))

        threads = [threading.Thread(target=client, args=(i,)) for i in range(128)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        labeled = sum(1 for lab, _ in results.values() if lab[0] >= 0)
        print(f"128 concurrent queries: {labeled} assigned to clusters")
        print("per-request selection knob:",
              f"eom -> {eng.labels(8).max() + 1} clusters,",
              f"leaf -> {eng.labels(8, cluster_selection_method='leaf').max() + 1}")
        print("engine stats:", eng.stats())


if __name__ == "__main__":
    main()
