"""Serve a small LM with batched requests through the decode engine.

  PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.lm import Engine, GenRequest


def main():
    cfg = get_config("gemma3_4b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(prompt=rng.integers(2, cfg.vocab, size=rng.integers(3, 12)).astype(np.int32),
                   max_new_tokens=24, temperature=0.8)
        for _ in range(8)
    ]
    outs = eng.generate(reqs, seed=1)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt_len={len(reqs[i].prompt)} -> {len(o)} tokens: {o[:10]}...")
    print("engine stats:", eng.last_stats)


if __name__ == "__main__":
    main()
