"""mamba2-780m [ssm] — 48L d=1536 attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality): expand=2 (d_inner=3072), headdim=64 => 48 SSD
heads, chunked scan (chunk 256), causal conv k=4.  [arXiv:2405.21060]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    arch="mamba2",
    vocab=50280,
    d_model=1536,
    n_layers=48,
    d_state=128,
    expand=2,
    ssm_head=64,
    ssd_chunk=256,
    d_conv=4,
    run_long_500k=True,             # O(1) recurrent state
)
