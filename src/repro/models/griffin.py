"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local attention, 1:2.

Block pattern (R, R, A) repeating: two gated-linear-recurrence (RG-LRU)
mixing blocks per local-MQA attention block; every mixing block is followed
by a GeGLU MLP residual (Griffin layout).

Scan strategy: the repeating PERIOD is the scan body (params stacked over
n_periods), so the mixed R/R/A structure stays a compact HLO; remainder
layers (26 = 3x8 + 2) are applied unrolled after the scan.

Train-time recurrence: jax.lax.associative_scan over the sequence (parallel
prefix for h_t = a_t * h_{t-1} + b_t).  Decode: O(1) state update; attention
cache is a RING BUFFER of size window (the arch's long-context win: the
long_500k cell carries a 2048-slot cache, not 500k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers as L

_C_RGLRU = 8.0


def _pattern(cfg):
    pat = cfg.block_pattern or ("R", "R", "A")
    period = len(pat)
    n_periods = cfg.n_layers // period
    remainder = tuple(pat[: cfg.n_layers - n_periods * period])
    return pat, n_periods, remainder


def _init_rglru_block(key, cfg):
    kk = jax.random.split(key, 6)
    d, w = cfg.d_model, cfg.d_model  # lru width = d_model
    p, s = {}, {}
    p["ln"], s["ln"] = L.rmsnorm_init(d)
    p["wx"], s["wx"] = L.dense_init(kk[0], (d, w), ("embed", "lru"), jnp.float32)
    p["wy"], s["wy"] = L.dense_init(kk[1], (d, w), ("embed", "lru"), jnp.float32)
    p["conv_w"], s["conv_w"] = (
        jax.random.normal(kk[2], (cfg.d_conv, w), jnp.float32) * 0.2,
        ("conv", "lru"),
    )
    p["conv_b"], s["conv_b"] = jnp.zeros((w,), jnp.float32), ("lru",)
    p["wr"], s["wr"] = L.dense_init(kk[3], (w, w), ("lru", "lru2"), jnp.float32)
    p["wi"], s["wi"] = L.dense_init(kk[4], (w, w), ("lru", "lru2"), jnp.float32)
    p["lam"], s["lam"] = (
        jnp.linspace(-4.0, -9.0, w).astype(jnp.float32),
        ("lru",),
    )
    p["wo"], s["wo"] = L.dense_init(kk[5], (w, d), ("lru", "embed"), jnp.float32)
    return p, s


def _init_attn_block(key, cfg):
    kk = jax.random.split(key, 4)
    d = cfg.d_model
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv * cfg.d_head
    p, s = {}, {}
    p["ln"], s["ln"] = L.rmsnorm_init(d)
    p["wq"], s["wq"] = L.dense_init(kk[0], (d, hq), ("embed", "heads_dim"), jnp.float32)
    p["wk"], s["wk"] = L.dense_init(kk[1], (d, hkv), ("embed", "kv_dim"), jnp.float32)
    p["wv"], s["wv"] = L.dense_init(kk[2], (d, hkv), ("embed", "kv_dim"), jnp.float32)
    p["wo"], s["wo"] = L.dense_init(kk[3], (hq, d), ("heads_dim", "embed"), jnp.float32)
    return p, s


def _init_mlp_block(key, cfg):
    p, s = {}, {}
    p["ln"], s["ln"] = L.rmsnorm_init(cfg.d_model)
    mp, ms = L.init_mlp(key, cfg, cfg.d_ff)
    p.update(mp)
    s.update(ms)
    return p, s


def init(cfg, key):
    pat, n_periods, remainder = _pattern(cfg)
    ks = iter(jax.random.split(key, 16))
    d = cfg.d_model
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
    )
    p["final_norm"], s["final_norm"] = L.rmsnorm_init(d)

    def stack(initfn, count, base_key):
        outs = [initfn(jax.random.fold_in(base_key, i), cfg) for i in range(count)]
        params = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
        specs = jax.tree.map(
            lambda sp: ("layers",) + sp,
            outs[0][1],
            is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, str) for e in v),
        )
        return params, specs

    period = {}
    period_s = {}
    for slot, kind in enumerate(pat):
        fn = _init_rglru_block if kind == "R" else _init_attn_block
        period[f"mix{slot}"], period_s[f"mix{slot}"] = stack(fn, n_periods, next(ks))
        period[f"mlp{slot}"], period_s[f"mlp{slot}"] = stack(
            _init_mlp_block, n_periods, next(ks)
        )
    p["period"], s["period"] = period, period_s

    rem, rem_s = {}, {}
    for slot, kind in enumerate(remainder):
        fn = _init_rglru_block if kind == "R" else _init_attn_block
        rem[f"mix{slot}"], rem_s[f"mix{slot}"] = fn(next(ks), cfg)
        rem[f"mlp{slot}"], rem_s[f"mlp{slot}"] = _init_mlp_block(next(ks), cfg)
    p["remainder"], s["remainder"] = rem, rem_s
    return p, s


def _rglru(pl, h, state=None, single_step=False):
    """Gated linear recurrence. h: (B,S,D). Returns (y, (conv_state, lru_state))."""
    dt = h.dtype
    x = h @ pl["wx"].astype(dt)
    y_gate = jax.nn.gelu((h @ pl["wy"].astype(dt)), approximate=True)
    conv_state = state[0] if state is not None else None
    x, conv_new = _conv(pl, x, conv_state)
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ pl["wr"])
    i = jax.nn.sigmoid(xf @ pl["wi"])
    log_a = -_C_RGLRU * jax.nn.softplus(pl["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    if single_step:
        h_prev = state[1]
        h_new = a[:, 0] * h_prev + b[:, 0]
        out = h_new[:, None]
        lru_new = h_new
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        out = hs
        lru_new = hs[:, -1]
    out = (out * y_gate.astype(jnp.float32)).astype(dt)
    return out @ pl["wo"].astype(dt), (conv_new, lru_new)


def _conv(pl, x, state):
    k = pl["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * pl["conv_w"][i].astype(x.dtype) for i in range(k))
    return out + pl["conv_b"].astype(x.dtype), xp[:, -(k - 1) :, :]


def _attn(pl, h, cfg, positions, k_pos, kv_valid, cache_kv=None):
    b, sq, d = h.shape
    dt = h.dtype
    q = (h @ pl["wq"].astype(dt)).reshape(b, sq, cfg.n_heads, cfg.d_head)
    k = (h @ pl["wk"].astype(dt)).reshape(b, sq, cfg.n_kv, cfg.d_head)
    v = (h @ pl["wv"].astype(dt)).reshape(b, sq, cfg.n_kv, cfg.d_head)
    q = L.rope(q, positions[None, :], cfg.rope_theta)
    k = L.rope(k, positions[None, :], cfg.rope_theta)
    if cache_kv is not None:
        k_all, v_all = cache_kv
    else:
        k_all, v_all = k, v
    o = L.attention(
        q, k_all, v_all, q_pos=positions, k_pos=k_pos,
        window=cfg.window, kv_valid=kv_valid,
    )
    return o.reshape(b, sq, -1) @ pl["wo"].astype(dt), (k, v)


def _apply_block(kind, mix_p, mlp_p, x, cfg, positions, state=None, single=False):
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    h = L.rmsnorm(x, mix_p["ln"])
    if kind == "R":
        out, new_state = _rglru(mix_p, h, state, single_step=single)
    else:
        out, kv = _attn(mix_p, h, cfg, positions, positions, None)
        new_state = None
    x = x + out
    h2 = L.rmsnorm(x, mlp_p["ln"])
    x = x + L.mlp({k: v for k, v in mlp_p.items() if k != "ln"}, h2, cfg, cfg.d_ff)
    return x, new_state


def forward(p, cfg, tokens, patch_embeds=None):
    pat, n_periods, remainder = _pattern(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[tokens]
    s_len = tokens.shape[1]
    positions = jnp.arange(s_len, dtype=jnp.int32)

    def body(x, period_params):
        for slot, kind in enumerate(pat):
            x, _ = _apply_block(
                kind, period_params[f"mix{slot}"], period_params[f"mlp{slot}"],
                x, cfg, positions,
            )
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p["period"])
    for slot, kind in enumerate(remainder):
        x, _ = _apply_block(
            kind, p["remainder"][f"mix{slot}"], p["remainder"][f"mlp{slot}"],
            x, cfg, positions,
        )
    x = L.rmsnorm(x, p["final_norm"])
    return x, jnp.float32(0.0)


def logits_fn(p, cfg, x):
    return x @ p["embed"].astype(x.dtype).T


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Ring-buffer attention cache (window slots) + O(1) recurrent states."""
    pat, n_periods, remainder = _pattern(cfg)
    n_attn_p = sum(1 for k in pat if k == "A")
    n_r_p = sum(1 for k in pat if k == "R")
    win = min(cfg.window, max_len)
    cache = {
        "k": jnp.zeros((n_periods * n_attn_p, batch, win, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((n_periods * n_attn_p, batch, win, cfg.n_kv, cfg.d_head), dtype),
        "kpos": jnp.full((win,), -(2**30), jnp.int32),
        "conv": jnp.zeros(
            (n_periods * n_r_p + sum(1 for k in remainder if k == "R"),
             batch, cfg.d_conv - 1, cfg.d_model), dtype),
        "lru": jnp.zeros(
            (n_periods * n_r_p + sum(1 for k in remainder if k == "R"),
             batch, cfg.d_model), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
    return cache


def decode_step(p, cfg, cache, cur_tokens):
    pat, n_periods, remainder = _pattern(cfg)
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x = p["embed"].astype(dt)[cur_tokens]
    positions = pos[None].astype(jnp.int32)
    win = cache["k"].shape[2]
    slot = pos % win
    kpos = cache["kpos"].at[slot].set(pos)

    r_per_period = sum(1 for k in pat if k == "R")
    a_per_period = sum(1 for k in pat if k == "A")

    def period_body(carry, xs):
        x, cache, pi = carry
        pp = xs
        ri = pi * r_per_period
        ai = pi * a_per_period
        for slot_i, kind in enumerate(pat):
            mix_p = pp[f"mix{slot_i}"]
            mlp_p = pp[f"mlp{slot_i}"]
            h = L.rmsnorm(x, mix_p["ln"])
            if kind == "R":
                out, (conv_new, lru_new) = _rglru(
                    mix_p, h, (cache["conv"][ri], cache["lru"][ri]), single_step=True
                )
                cache = dict(
                    cache,
                    conv=jax.lax.dynamic_update_index_in_dim(
                        cache["conv"], conv_new.astype(cache["conv"].dtype), ri, 0),
                    lru=jax.lax.dynamic_update_index_in_dim(cache["lru"], lru_new, ri, 0),
                )
                ri = ri + 1
            else:
                _, (k_new, v_new) = _attn(mix_p, h, cfg, positions, positions, None)
                k_all = jax.lax.dynamic_update_slice(
                    cache["k"][ai], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
                v_all = jax.lax.dynamic_update_slice(
                    cache["v"][ai], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
                cache = dict(
                    cache,
                    k=jax.lax.dynamic_update_index_in_dim(cache["k"], k_all, ai, 0),
                    v=jax.lax.dynamic_update_index_in_dim(cache["v"], v_all, ai, 0),
                )
                out, _ = _attn(
                    mix_p, h, cfg, positions, kpos, kpos >= 0,
                    (k_all.astype(dt), v_all.astype(dt)),
                )
                ai = ai + 1
            x = x + out
            h2 = L.rmsnorm(x, mlp_p["ln"])
            x = x + L.mlp({k: v for k, v in mlp_p.items() if k != "ln"}, h2, cfg, cfg.d_ff)
        return (x, cache, pi + 1), None

    (x, cache, _), _ = jax.lax.scan(
        period_body, (x, cache, jnp.int32(0)), p["period"]
    )
    rrem = n_periods * r_per_period
    for slot_i, kind in enumerate(remainder):
        mix_p = p["remainder"][f"mix{slot_i}"]
        mlp_p = p["remainder"][f"mlp{slot_i}"]
        h = L.rmsnorm(x, mix_p["ln"])
        out, (conv_new, lru_new) = _rglru(
            mix_p, h, (cache["conv"][rrem], cache["lru"][rrem]), single_step=True)
        cache = dict(
            cache,
            conv=cache["conv"].at[rrem].set(conv_new.astype(cache["conv"].dtype)),
            lru=cache["lru"].at[rrem].set(lru_new),
        )
        rrem += 1
        x = x + out
        h2 = L.rmsnorm(x, mlp_p["ln"])
        x = x + L.mlp({k: v for k, v in mlp_p.items() if k != "ln"}, h2, cfg, cfg.d_ff)

    x = L.rmsnorm(x, p["final_norm"])
    logits = logits_fn(p, cfg, x)
    return logits[:, 0], dict(cache, kpos=kpos, pos=pos + 1)


def prefill(p, cfg, tokens, max_len: int, patch_embeds=None, cache_dtype=jnp.bfloat16):
    """One forward pass that also collects decode states.

    R blocks: conv tail + final LRU state (both fall out of the scan).
    A blocks: the last `window` positions' K/V scattered into ring slots
    (slot(p) = p % window), so decode continues the ring seamlessly.
    """
    pat, n_periods, remainder = _pattern(cfg)
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[tokens]
    s_len = tokens.shape[1]
    positions = jnp.arange(s_len, dtype=jnp.int32)
    win = min(cfg.window, max_len)
    keep = min(win, s_len)
    p_sel = jnp.arange(s_len - keep, s_len)
    slots = p_sel % win

    def ring(k):
        """(B, S, Hkv, Dh) -> (B, win, Hkv, Dh) ring-indexed."""
        out = jnp.zeros((k.shape[0], win) + k.shape[2:], cache_dtype)
        return out.at[:, slots].set(k[:, p_sel].astype(cache_dtype))

    def body(x, period_params):
        states = {}
        for slot_i, kind in enumerate(pat):
            mix_p = period_params[f"mix{slot_i}"]
            mlp_p = period_params[f"mlp{slot_i}"]
            h = L.rmsnorm(x, mix_p["ln"])
            if kind == "R":
                out, (conv_new, lru_new) = _rglru(mix_p, h)
                states[f"conv{slot_i}"] = conv_new.astype(cache_dtype)
                states[f"lru{slot_i}"] = lru_new
            else:
                out, (k, v) = _attn(mix_p, h, cfg, positions, positions, None)
                states[f"k{slot_i}"] = ring(k)
                states[f"v{slot_i}"] = ring(v)
            x = x + out
            h2 = L.rmsnorm(x, mlp_p["ln"])
            x = x + L.mlp(
                {k_: v_ for k_, v_ in mlp_p.items() if k_ != "ln"}, h2, cfg, cfg.d_ff
            )
        return x, states

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, period_states = jax.lax.scan(body_fn, x, p["period"])

    rem_conv, rem_lru = [], []
    for slot_i, kind in enumerate(remainder):
        mix_p = p["remainder"][f"mix{slot_i}"]
        mlp_p = p["remainder"][f"mlp{slot_i}"]
        h = L.rmsnorm(x, mix_p["ln"])
        out, (conv_new, lru_new) = _rglru(mix_p, h)
        rem_conv.append(conv_new.astype(cache_dtype))
        rem_lru.append(lru_new)
        x = x + out
        h2 = L.rmsnorm(x, mlp_p["ln"])
        x = x + L.mlp(
            {k_: v_ for k_, v_ in mlp_p.items() if k_ != "ln"}, h2, cfg, cfg.d_ff
        )

    x = L.rmsnorm(x, p["final_norm"])
    logits = logits_fn(p, cfg, x[:, -1:])

    # assemble the cache in init_cache layout
    r_slots = [i for i, k in enumerate(pat) if k == "R"]
    a_slots = [i for i, k in enumerate(pat) if k == "A"]
    # (n_periods, B, ...) per slot -> interleave to (n_periods * per, B, ...)
    def interleave(names):
        per = len(names)
        stacked = jnp.stack([period_states[nm] for nm in names], axis=1)
        return stacked.reshape((n_periods * per,) + stacked.shape[2:])

    conv = interleave([f"conv{i}" for i in r_slots])
    lru = interleave([f"lru{i}" for i in r_slots])
    if rem_conv:
        conv = jnp.concatenate([conv, jnp.stack(rem_conv)], axis=0)
        lru = jnp.concatenate([lru, jnp.stack(rem_lru)], axis=0)
    kc = interleave([f"k{i}" for i in a_slots])
    vc = interleave([f"v{i}" for i in a_slots])
    kpos = jnp.full((win,), -(2**30), jnp.int32).at[slots].set(p_sel.astype(jnp.int32))
    cache = {
        "k": kc, "v": vc, "kpos": kpos, "conv": conv, "lru": lru,
        "pos": jnp.int32(s_len),
    }
    return logits[:, 0], cache
