"""`MultiHDBSCAN`: sklearn-style front door for the multi-density engine.

One ``fit`` buys the whole mpts range (the paper's "hundred hierarchies for
the cost of ~2 HDBSCAN* runs"): a single kNN pass, a single RNG^kmax, one
batched Borůvka over every reweighting.  Everything *per-mpts* — the
dendrogram condensation, cluster selection, labels — is extracted lazily and
cached: the first extraction request runs the batched device single-linkage
for the full range (core.linkage), after which each ``labels_for(mpts)`` is
a cheap vectorized host pass.

Estimator surface (in the spirit of McInnes & Healy's hdbscan API, with
Malzer & Baum-style selection options):

  fit(X) / fit_predict(X, mpts=...)
  labels_for(mpts) / hierarchy_for(mpts) / probabilities_for(mpts)
  mpts_profile()  — the paper's "which density level reveals which structure"
                    exploration as one query
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .. import engine
from ..core import multi


class MultiHDBSCAN:
    """All HDBSCAN* hierarchies for mpts in [kmin, kmax] from one fit.

    Parameters
    ----------
    kmax : int
        Largest mpts (neighbourhood size) in the range; one (kmax-1)-NN pass
        and one RNG^kmax serve the whole range.
    kmin : int
        Smallest mpts in the range (default 2).
    mpts_values : sequence of int, optional
        Explicit subset of the range to compute MSTs for (default: all of
        [kmin, kmax]).
    min_cluster_size : int, optional
        Condensation threshold; default per-mpts ``max(2, mpts)``.
    cluster_selection_method : {"eom", "leaf"}
        Excess-of-mass (HDBSCAN* default) or condensed-tree leaves.
    allow_single_cluster : bool
        Permit the root as a selected cluster.
    variant : {"rng_ss", "rng_star", "rng"}
        RNG^kmax graph variant (paper §IV); rng_star is the default
        speed/size tradeoff.
    backend : str, optional
        Kernel backend ("pallas", "pallas_interpret", "jnp", "ref");
        default auto-selects per platform.
    mesh : jax.sharding.Mesh, optional
        Device mesh for the sharded execution engine.  When the mesh has a
        non-trivial ``data`` axis the row-parallel stages (kNN, exact lune
        scan, the per-mpts Borůvka range) shard over it; a 1-device mesh
        (or ``None``) degrades to the single-device path, so the SAME user
        code runs on a laptop and a pod (``dist.sharding`` resolve-rules
        philosophy).
    plan : "auto" | "single" | "mesh" | engine.Plan
        Placement request, resolved once at ``fit`` against ``mesh``:
        "auto" shards iff the mesh is usable, "single" forces the local
        path, "mesh" errors rather than silently degrading.  Pass a
        pre-built ``engine.Plan`` to pin every chunk/tile size explicitly.
    """

    def __init__(
        self,
        kmax: int = 16,
        *,
        kmin: int = 2,
        mpts_values: Sequence[int] | None = None,
        min_cluster_size: int | None = None,
        cluster_selection_method: str = "eom",
        allow_single_cluster: bool = False,
        variant: str = "rng_star",
        backend: str | None = None,
        mesh=None,
        plan: "engine.Plan | str" = "auto",
    ):
        if cluster_selection_method not in ("eom", "leaf"):
            raise ValueError(
                "cluster_selection_method must be 'eom' or 'leaf'; "
                f"got {cluster_selection_method!r}"
            )
        if kmax < 2:
            raise ValueError(f"kmax must be >= 2; got {kmax}")
        multi._validate_min_cluster_size(min_cluster_size)
        if not 2 <= kmin <= kmax:
            raise ValueError(f"need 2 <= kmin <= kmax; got kmin={kmin}, kmax={kmax}")
        self.kmax = kmax
        self.kmin = kmin
        self.mpts_values = list(mpts_values) if mpts_values is not None else None
        self.min_cluster_size = min_cluster_size
        self.cluster_selection_method = cluster_selection_method
        self.allow_single_cluster = allow_single_cluster
        self.variant = variant
        self.backend = backend
        self.mesh = mesh
        self.plan = plan

        self._msts: multi.MultiMSTResult | None = None
        self._linkage: multi.LinkageRange | None = None
        self._hierarchy_cache: dict[int, multi.HierarchyResult] = {}

    # -- fitting -----------------------------------------------------------

    def fit(self, X) -> "MultiHDBSCAN":
        """Compute the shared graph and every per-mpts MST (no extraction)."""
        X = np.asarray(X)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-d (n_samples, n_features); got {X.shape}")
        if X.shape[0] <= self.kmax:
            raise ValueError(
                f"n_samples must exceed kmax; got n={X.shape[0]}, kmax={self.kmax}"
            )
        if not (np.issubdtype(X.dtype, np.number) or X.dtype == np.bool_):
            raise ValueError(f"X must be numeric; got dtype {X.dtype}")
        # NaN/inf would otherwise flow unchecked into the host WSPD
        # fair-split tree (poisoning bbox splits) and the f32 tie-epsilon
        # machinery (NaN never compares, silently dropping candidates) —
        # reject here with a usable message.  Duplicated points are legal:
        # the tie tolerance keeps every tied SBCN/MST choice, and the fused
        # cascade falls back to the dense slot path under mass ties.
        bad = ~np.isfinite(X)
        if bad.any():
            rows = np.flatnonzero(bad.any(axis=1))
            raise ValueError(
                f"X contains {int(bad.sum())} non-finite value(s) "
                f"(NaN or inf) in {len(rows)} row(s), first at row "
                f"{int(rows[0])}; clean or impute before fit()"
            )
        # resolve the execution plan ONCE: backend + mesh placement + sizes
        self.plan_ = engine.resolve_plan(
            self.plan, backend=self.backend, mesh=self.mesh
        )
        self._msts = multi.fit_msts(
            X,
            self.kmax,
            kmin=self.kmin,
            variant=self.variant,
            mpts_values=self.mpts_values,
            plan=self.plan_,
        )
        self._linkage = None
        self._hierarchy_cache = {}
        self.n_features_in_ = X.shape[1]
        self.n_samples_ = X.shape[0]
        self.mpts_values_ = list(self._msts.mpts_values)
        self.timings_ = dict(self._msts.timings)
        return self

    def fit_predict(self, X, mpts: int | None = None) -> np.ndarray:
        """fit + labels at one density level (default: the largest, kmax)."""
        self.fit(X)
        labels = self.labels_for(mpts if mpts is not None else self.mpts_values_[-1])
        self.labels_ = labels
        return labels

    # -- lazy batched extraction ------------------------------------------

    def _check_fitted(self) -> multi.MultiMSTResult:
        if self._msts is None:
            raise RuntimeError("MultiHDBSCAN instance is not fitted yet; call fit(X)")
        return self._msts

    def _ensure_linkage(self) -> multi.LinkageRange:
        """All dendrograms for the range in ONE device program, on first need."""
        msts = self._check_fitted()
        if self._linkage is None:
            self._linkage = multi.linkage_range(msts)
        return self._linkage

    def hierarchy_for(self, mpts: int) -> multi.HierarchyResult:
        """Condensed tree / stabilities / labels at one density level (cached)."""
        msts = self._check_fitted()
        if mpts not in self._hierarchy_cache:
            self._hierarchy_cache[mpts] = multi.extract_one_from_linkage(
                msts,
                self._ensure_linkage(),
                msts.row_of(mpts),
                min_cluster_size=self.min_cluster_size,
                allow_single_cluster=self.allow_single_cluster,
                cluster_selection_method=self.cluster_selection_method,
            )
        return self._hierarchy_cache[mpts]

    def labels_for(self, mpts: int) -> np.ndarray:
        """Cluster labels (-1 = noise) at one density level (cached)."""
        return self.hierarchy_for(mpts).labels

    def mst_for(self, mpts: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(ea, eb, w) MST edges under mutual reachability at this mpts."""
        msts = self._check_fitted()
        row = msts.row_of(mpts)
        return msts.mst_ea[row], msts.mst_eb[row], msts.mst_w[row]

    @property
    def graph_(self):
        """The fitted RNG^kmax (RngGraph: edges, d2, variant, stats)."""
        return self._check_fitted().graph

    @property
    def n_graph_edges_(self) -> int:
        """Edge count of the shared RNG^kmax (vs n(n-1)/2 for the baseline)."""
        return len(self.graph_.edges)

    # -- range-level queries ----------------------------------------------

    def mpts_profile(self) -> list[dict]:
        """Stability-across-mpts summary: one row per density level.

        Each row reports how the clustering looks at that mpts — the paper's
        multi-density exploration ("which density level reveals which
        cluster") as a single query.  ``total_stability`` sums selected-
        cluster excess-of-mass; comparisons across mpts are indicative (the
        lambda scale shifts with density), so treat it as a ranking aid, not
        an absolute score.
        """
        msts = self._check_fitted()
        rows = []
        for mpts in msts.mpts_values:
            h = self.hierarchy_for(mpts)
            sizes = np.bincount(h.labels[h.labels >= 0], minlength=h.n_clusters)
            selected_stab = sorted(
                (h.stability.get(c, 0.0) for c in h.selected), reverse=True
            )
            rows.append({
                "mpts": mpts,
                "n_clusters": h.n_clusters,
                "n_noise": int((h.labels == -1).sum()),
                "cluster_sizes": sizes.tolist(),
                "max_stability": float(selected_stab[0]) if selected_stab else 0.0,
                "total_stability": float(sum(selected_stab)),
            })
        return rows

    def __repr__(self) -> str:
        fitted = "" if self._msts is None else f", fitted n={self.n_samples_}"
        place = ""
        if getattr(self, "plan_", None) is not None:
            place = f", plan={self.plan_.describe()}"
        return (
            f"MultiHDBSCAN(kmax={self.kmax}, kmin={self.kmin}, "
            f"variant={self.variant!r}, "
            f"cluster_selection_method={self.cluster_selection_method!r}"
            f"{place}{fitted})"
        )
