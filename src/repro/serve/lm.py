"""Minimal batched LM serving engine: prefill -> decode loop with sampling.

Production posture without production scope: a fixed-batch continuous loop
(join at prefill boundaries), per-request greedy/temperature sampling, EOS
early-exit masking, and jitted step functions shared across requests.  Used
by examples/serve_lm.py and the serve smoke tests.  (The clustering serve
surface — the repo's actual workload — lives in ``serve.engine``.)
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0    # 0 => greedy
    eos_id: int = 1


class Engine:
    def __init__(self, cfg, params, max_len: int = 512, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.max_len = max_len
        self.cache_dtype = cache_dtype

        def _prefill(params, tokens):
            return self.model.prefill(
                params, cfg, tokens, max_len=max_len, cache_dtype=cache_dtype
            )

        def _decode(params, cache, cur, key, temps):
            # temps is (b,): each request samples at ITS OWN temperature —
            # a batch must never inherit request 0's setting
            logits, cache = self.model.decode_step(params, cfg, cache, cur)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(
                key, logits / jnp.maximum(temps, 1e-6)[:, None]
            )
            nxt = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
            return nxt[:, None], cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def generate(self, requests: list[GenRequest], seed: int = 0) -> list[np.ndarray]:
        """Batched generation; prompts are right-aligned padded to equal len."""
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with BOS=0
        max_new = max(r.max_new_tokens for r in requests)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        eos = np.asarray([r.eos_id for r in requests], np.int32)

        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        cur = np.asarray(nxt)
        outs = [cur]
        key = jax.random.PRNGKey(seed)
        done = cur[:, 0] == eos
        for _ in range(max_new - 1):
            if done.all():
                break
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, cache, nxt, sub, temps)
            cur = np.asarray(nxt)
            # rows that already emitted EOS keep emitting EOS: sampled junk
            # from finished rows must never reach results or the stats
            cur = np.where(done[:, None], eos[:, None], cur)
            outs.append(cur)
            done |= cur[:, 0] == eos
        dt = time.monotonic() - t0
        gen = np.concatenate(outs, axis=1)
        results = []
        for i, r in enumerate(requests):
            row = gen[i][: r.max_new_tokens]
            hit = np.nonzero(row == r.eos_id)[0]
            results.append(row[: hit[0] + 1] if len(hit) else row)
        # per-request generated counts stop at EOS, so the throughput stat
        # reflects real tokens, not padding decoded for the batch laggards
        n_tokens = int(sum(len(r) for r in results))
        self.last_stats = {
            "wall_s": dt,
            "tokens": n_tokens,
            "tok_per_s": float(n_tokens / max(dt, 1e-9)),
            "batch_steps": int(gen.shape[1]),
        }
        return results
