"""Numpy/scipy oracles for the paper's constructions (tests only — src never
imports scipy at runtime).

These implement the DEFINITIONS directly (O(n^2)/O(n^3)) and are the ground
truth for: core distances, mrd, the RNG (Def. 1), MSTs of G_mpts, and the
naive per-mpts HDBSCAN* baseline.
"""

from __future__ import annotations

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import minimum_spanning_tree


def pairwise_d(x: np.ndarray) -> np.ndarray:
    d2 = (
        np.sum(x**2, -1)[:, None]
        + np.sum(x**2, -1)[None, :]
        - 2.0 * x @ x.T
    )
    return np.sqrt(np.maximum(d2, 0.0))


def core_distances(x: np.ndarray, kmax: int) -> np.ndarray:
    """(n, kmax): column j-1 = c_j = distance to j-th NN *including self*."""
    d = pairwise_d(x)
    ds = np.sort(d, axis=1)  # column 0 is the self distance (0)
    return ds[:, :kmax]


def mrd_matrix(x: np.ndarray, mpts: int, cd: np.ndarray | None = None) -> np.ndarray:
    """Dense mutual-reachability matrix for one mpts (Eq. 1)."""
    d = pairwise_d(x)
    if cd is None:
        cd = core_distances(x, mpts)
    c = cd[:, mpts - 1]
    m = np.maximum(np.maximum(c[:, None], c[None, :]), d)
    np.fill_diagonal(m, 0.0)
    return m


def rng_naive(m: np.ndarray) -> np.ndarray:
    """Exact RNG adjacency for a dense distance matrix (Def. 1), O(n^3).

    Edge (a,b) iff  m[a,b] <= max(m[a,c], m[b,c]) for all c != a, b.
    """
    n = m.shape[0]
    mx = np.maximum(m[:, None, :], m[None, :, :])  # (a, b, c)
    # exclude c == a and c == b from the min
    eye = np.eye(n, dtype=bool)
    excl = eye[:, None, :] | eye[None, :, :]
    mx = np.where(excl, np.inf, mx)
    lune_min = mx.min(axis=2)
    adj = m <= lune_min
    np.fill_diagonal(adj, False)
    return adj


def mst_weights(m: np.ndarray) -> np.ndarray:
    """Sorted MST edge weights of a dense graph (unique multiset for any MST)."""
    t = minimum_spanning_tree(csr_matrix(m))
    return np.sort(t.data)


def mst_weights_edge_list(
    ea: np.ndarray, eb: np.ndarray, w: np.ndarray, n: int
) -> np.ndarray:
    """Sorted MST edge weights of an explicit edge-list graph (scipy).

    NB: scipy's csr_matrix SUMS duplicate entries; multigraph edges must be
    deduplicated to their minimum weight first.
    """
    lo = np.minimum(ea, eb).astype(np.int64)
    hi = np.maximum(ea, eb).astype(np.int64)
    key = lo * n + hi
    order = np.lexsort((w, key))
    key_s, w_s = key[order], w[order]
    first = np.concatenate([[True], np.diff(key_s) != 0])
    key_u, w_u = key_s[first], w_s[first]
    g = csr_matrix((w_u, (key_u // n, key_u % n)), shape=(n, n))
    t = minimum_spanning_tree(g)
    return np.sort(t.data)


def mst_edges_dense(m: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(ea, eb, w) MST edges of a dense graph via scipy."""
    t = minimum_spanning_tree(csr_matrix(m)).tocoo()
    return t.row, t.col, t.data
