"""`MultiHDBSCAN`: sklearn-style front door for the multi-density engine.

One ``fit`` buys the whole mpts range (the paper's "hundred hierarchies for
the cost of ~2 HDBSCAN* runs"): a single kNN pass, a single RNG^kmax, one
batched Borůvka over every reweighting.  Everything *per-mpts* — the
dendrogram condensation, cluster selection, labels — is extracted lazily and
cached: the first extraction request runs the batched device single-linkage
for the full range (core.linkage), after which each ``labels_for(mpts)`` is
a cheap vectorized host pass.

Estimator surface (in the spirit of McInnes & Healy's hdbscan API, with
Malzer & Baum-style selection options):

  fit(X) / fit_predict(X, mpts=...)
  labels_for(mpts) / hierarchy_for(mpts) / probabilities_for(mpts)
  mpts_profile()  — the paper's "which density level reveals which structure"
                    exploration as one query
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import numpy as np

from .. import engine
from ..core import dbcv as dbcv_mod
from ..core import multi, predict


@dataclasses.dataclass
class Membership:
    """Per-fitted-point view of one density level: labels + strengths."""

    mpts: int
    labels: np.ndarray         # (n,) int64, -1 = noise
    probabilities: np.ndarray  # (n,) float64 in [0, 1], 0 for noise
    lambdas: np.ndarray        # (n,) float64 departure lambda (0 for noise)


class MultiHDBSCAN:
    """All HDBSCAN* hierarchies for mpts in [kmin, kmax] from one fit.

    Parameters
    ----------
    kmax : int
        Largest mpts (neighbourhood size) in the range; one (kmax-1)-NN pass
        and one RNG^kmax serve the whole range.
    kmin : int
        Smallest mpts in the range (default 2).
    mpts_values : sequence of int, optional
        Explicit subset of the range to compute MSTs for (default: all of
        [kmin, kmax]).
    min_cluster_size : int, optional
        Condensation threshold; default per-mpts ``max(2, mpts)``.
    cluster_selection_method : {"eom", "leaf"}
        Excess-of-mass (HDBSCAN* default) or condensed-tree leaves.
    allow_single_cluster : bool
        Permit the root as a selected cluster.
    variant : {"rng_ss", "rng_star", "rng"}
        RNG^kmax graph variant (paper §IV); rng_star is the default
        speed/size tradeoff.
    backend : str, optional
        Kernel backend ("pallas", "pallas_interpret", "jnp", "ref");
        default auto-selects per platform.
    mesh : jax.sharding.Mesh, optional
        Device mesh for the sharded execution engine.  When the mesh has a
        non-trivial ``data`` axis the row-parallel stages (kNN, exact lune
        scan, the per-mpts Borůvka range) shard over it; a 1-device mesh
        (or ``None``) degrades to the single-device path, so the SAME user
        code runs on a laptop and a pod (``dist.sharding`` resolve-rules
        philosophy).
    plan : "auto" | "single" | "mesh" | engine.Plan
        Placement request, resolved once at ``fit`` against ``mesh``:
        "auto" shards iff the mesh is usable, "single" forces the local
        path, "mesh" errors rather than silently degrading.  Pass a
        pre-built ``engine.Plan`` to pin every chunk/tile size explicitly.
    max_cached_hierarchies : int, optional
        Bound on the per-mpts extraction cache (LRU eviction).  ``None``
        (default) keeps every requested level — right for exploration;
        long-lived serving processes (``serve.ClusterServeEngine``) set a
        bound so a hostile query mix cannot hold all R condensed trees
        resident.
    """

    def __init__(
        self,
        kmax: int = 16,
        *,
        kmin: int = 2,
        mpts_values: Sequence[int] | None = None,
        min_cluster_size: int | None = None,
        cluster_selection_method: str = "eom",
        allow_single_cluster: bool = False,
        variant: str = "rng_star",
        backend: str | None = None,
        mesh=None,
        plan: "engine.Plan | str" = "auto",
        max_cached_hierarchies: int | None = None,
    ):
        if cluster_selection_method not in ("eom", "leaf"):
            raise ValueError(
                "cluster_selection_method must be 'eom' or 'leaf'; "
                f"got {cluster_selection_method!r}"
            )
        if kmax < 2:
            raise ValueError(f"kmax must be >= 2; got {kmax}")
        multi._validate_min_cluster_size(min_cluster_size)
        if not 2 <= kmin <= kmax:
            raise ValueError(f"need 2 <= kmin <= kmax; got kmin={kmin}, kmax={kmax}")
        self.kmax = kmax
        self.kmin = kmin
        self.mpts_values = list(mpts_values) if mpts_values is not None else None
        self.min_cluster_size = min_cluster_size
        self.cluster_selection_method = cluster_selection_method
        self.allow_single_cluster = allow_single_cluster
        self.variant = variant
        self.backend = backend
        self.mesh = mesh
        self.plan = plan
        if max_cached_hierarchies is not None and max_cached_hierarchies < 1:
            raise ValueError(
                f"max_cached_hierarchies must be >= 1 or None; "
                f"got {max_cached_hierarchies}"
            )
        self.max_cached_hierarchies = max_cached_hierarchies

        self._msts: multi.MultiMSTResult | None = None
        self._X: np.ndarray | None = None
        self._linkage: multi.LinkageRange | None = None
        self._hierarchy_cache: collections.OrderedDict[int, multi.HierarchyResult] = (
            collections.OrderedDict()
        )
        self._walk_cache: dict[int, predict.WalkTable] = {}

    # -- fitting -----------------------------------------------------------

    def fit(self, X) -> "MultiHDBSCAN":
        """Compute the shared graph and every per-mpts MST (no extraction)."""
        X = np.asarray(X)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-d (n_samples, n_features); got {X.shape}")
        if X.shape[0] <= self.kmax:
            raise ValueError(
                f"n_samples must exceed kmax; got n={X.shape[0]}, kmax={self.kmax}"
            )
        if not (np.issubdtype(X.dtype, np.number) or X.dtype == np.bool_):
            raise ValueError(f"X must be numeric; got dtype {X.dtype}")
        # NaN/inf would otherwise flow unchecked into the host WSPD
        # fair-split tree (poisoning bbox splits) and the f32 tie-epsilon
        # machinery (NaN never compares, silently dropping candidates) —
        # reject here with a usable message.  Duplicated points are legal:
        # the tie tolerance keeps every tied SBCN/MST choice, and the fused
        # cascade falls back to the dense slot path under mass ties.
        bad = ~np.isfinite(X)
        if bad.any():
            rows = np.flatnonzero(bad.any(axis=1))
            raise ValueError(
                f"X contains {int(bad.sum())} non-finite value(s) "
                f"(NaN or inf) in {len(rows)} row(s), first at row "
                f"{int(rows[0])}; clean or impute before fit()"
            )
        # resolve the execution plan ONCE: backend + mesh placement + sizes
        self.plan_ = engine.resolve_plan(
            self.plan, backend=self.backend, mesh=self.mesh
        )
        self._msts = multi.fit_msts(
            X,
            self.kmax,
            kmin=self.kmin,
            variant=self.variant,
            mpts_values=self.mpts_values,
            plan=self.plan_,
        )
        self._X = X  # retained for out-of-sample queries (approximate_predict)
        self._linkage = None
        self._hierarchy_cache = collections.OrderedDict()
        self._walk_cache = {}
        self.n_features_in_ = X.shape[1]
        self.n_samples_ = X.shape[0]
        self.mpts_values_ = list(self._msts.mpts_values)
        self.timings_ = dict(self._msts.timings)
        return self

    def fit_predict(self, X, mpts: int | None = None) -> np.ndarray:
        """fit + labels at one density level (default: the largest, kmax)."""
        self.fit(X)
        labels = self.labels_for(mpts if mpts is not None else self.mpts_values_[-1])
        self.labels_ = labels
        return labels

    # -- lazy batched extraction ------------------------------------------

    def _check_fitted(self) -> multi.MultiMSTResult:
        if self._msts is None:
            raise RuntimeError("MultiHDBSCAN instance is not fitted yet; call fit(X)")
        return self._msts

    def _ensure_linkage(self) -> multi.LinkageRange:
        """All dendrograms for the range in ONE device program, on first need."""
        msts = self._check_fitted()
        if self._linkage is None:
            self._linkage = multi.linkage_range(msts)
        return self._linkage

    def hierarchy_for(self, mpts: int) -> multi.HierarchyResult:
        """Condensed tree / stabilities / labels at one density level (cached).

        The cache is LRU-bounded when ``max_cached_hierarchies`` is set (the
        serving configuration); recently queried density levels stay hot,
        cold ones re-extract from the resident ``LinkageRange`` on demand.
        """
        msts = self._check_fitted()
        if mpts in self._hierarchy_cache:
            self._hierarchy_cache.move_to_end(mpts)
        else:
            self._hierarchy_cache[mpts] = multi.extract_one_from_linkage(
                msts,
                self._ensure_linkage(),
                msts.row_of(mpts),
                min_cluster_size=self.min_cluster_size,
                allow_single_cluster=self.allow_single_cluster,
                cluster_selection_method=self.cluster_selection_method,
            )
            bound = self.max_cached_hierarchies
            while bound is not None and len(self._hierarchy_cache) > bound:
                evicted, _ = self._hierarchy_cache.popitem(last=False)
                self._walk_cache.pop(evicted, None)
        return self._hierarchy_cache[mpts]

    def labels_for(self, mpts: int) -> np.ndarray:
        """Cluster labels (-1 = noise) at one density level (cached)."""
        return self.hierarchy_for(mpts).labels

    def membership_for(self, mpts: int) -> Membership:
        """Labels + membership probabilities + lambdas of the fitted points.

        The per-point probability is hdbscan-style: the departure lambda of
        the point relative to its cluster's deepest (finite) departure —
        1.0 at the cluster core, tapering toward the edge, 0 for noise.
        """
        h = self.hierarchy_for(mpts)
        return Membership(
            mpts=mpts,
            labels=h.labels,
            probabilities=predict.membership_probabilities(h),
            lambdas=np.asarray(h.point_lambda),
        )

    def probabilities_for(self, mpts: int) -> np.ndarray:
        """Cluster membership strength of each fitted point at one level.

        Values in [0, 1]; noise points score 0.  See ``membership_for`` for
        the labels + lambdas alongside.
        """
        return self.membership_for(mpts).probabilities

    def approximate_predict(
        self, Q, mpts: int | None = None
    ) -> "tuple[np.ndarray, np.ndarray] | predict.PredictResult":
        """Out-of-sample assignment of a query batch (no refit).

        One device pass ranks the batch against the fitted points and
        attaches every query for EVERY fitted mpts row at once; the cached
        condensed trees then supply labels and membership probabilities per
        level (McInnes & Healy's ``approximate_predict``, batched across
        the density range).

        With ``mpts`` given, returns ``(labels, probabilities)`` for that
        level (hdbscan-style).  With ``mpts=None``, returns the full
        :class:`~repro.core.predict.PredictResult` — (R, q) labels /
        probabilities / lambdas / attachment neighbours.
        """
        msts = self._check_fitted()
        Q = np.asarray(Q)
        predict.validate_queries(Q, self.n_features_in_)
        res = predict.predict_range(
            msts,
            self._X,
            Q,
            self.hierarchy_for,
            plan=self.plan_,
            mpts_values=None if mpts is None else [mpts],
            table_cache=self._walk_cache,
        )
        if mpts is None:
            return res
        return res.labels[0], res.probabilities[0]

    def dbcv_profile(self) -> list[dict]:
        """DBCV relative validity at every fitted density level.

        The paper's §I motivation as one query: an internal validity score
        per mpts (computed on the per-mpts mutual-reachability MST, the
        standard fast approximation), so callers can rank density levels
        without ground truth.  Returns ``[{"mpts", "dbcv", "n_clusters"}]``.
        """
        msts = self._check_fitted()
        rows = []
        for mpts in msts.mpts_values:
            h = self.hierarchy_for(mpts)
            rows.append({
                "mpts": mpts,
                "dbcv": dbcv_mod.dbcv_relative_validity(
                    h.mst_ea, h.mst_eb, h.mst_w, h.labels
                ),
                "n_clusters": h.n_clusters,
            })
        return rows

    def mst_for(self, mpts: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(ea, eb, w) MST edges under mutual reachability at this mpts."""
        msts = self._check_fitted()
        row = msts.row_of(mpts)
        return msts.mst_ea[row], msts.mst_eb[row], msts.mst_w[row]

    @property
    def graph_(self):
        """The fitted RNG^kmax (RngGraph: edges, d2, variant, stats)."""
        return self._check_fitted().graph

    @property
    def n_graph_edges_(self) -> int:
        """Edge count of the shared RNG^kmax (vs n(n-1)/2 for the baseline)."""
        return len(self.graph_.edges)

    # -- range-level queries ----------------------------------------------

    def mpts_profile(self) -> list[dict]:
        """Stability-across-mpts summary: one row per density level.

        Each row reports how the clustering looks at that mpts — the paper's
        multi-density exploration ("which density level reveals which
        cluster") as a single query.  ``total_stability`` sums selected-
        cluster excess-of-mass; comparisons across mpts are indicative (the
        lambda scale shifts with density), so treat it as a ranking aid, not
        an absolute score.
        """
        msts = self._check_fitted()
        rows = []
        for mpts in msts.mpts_values:
            h = self.hierarchy_for(mpts)
            sizes = np.bincount(h.labels[h.labels >= 0], minlength=h.n_clusters)
            selected_stab = sorted(
                (h.stability.get(c, 0.0) for c in h.selected), reverse=True
            )
            rows.append({
                "mpts": mpts,
                "n_clusters": h.n_clusters,
                "n_noise": int((h.labels == -1).sum()),
                "cluster_sizes": sizes.tolist(),
                "max_stability": float(selected_stab[0]) if selected_stab else 0.0,
                "total_stability": float(sum(selected_stab)),
            })
        return rows

    def __repr__(self) -> str:
        fitted = "" if self._msts is None else f", fitted n={self.n_samples_}"
        place = ""
        if getattr(self, "plan_", None) is not None:
            place = f", plan={self.plan_.describe()}"
        return (
            f"MultiHDBSCAN(kmax={self.kmax}, kmin={self.kmin}, "
            f"variant={self.variant!r}, "
            f"cluster_selection_method={self.cluster_selection_method!r}"
            f"{place}{fitted})"
        )
