"""Core library: the paper's contribution as composable JAX modules.

Public API:
  multi_hdbscan       — all hierarchies for mpts in [kmin, kmax] via RNG^kmax
  hdbscan_baseline    — optimized re-run baseline (shared kNN + dense MST)
  build_rng_graph     — the single RNG^kmax (variants rng_ss / rng_star / rng)
  boruvka_mst(_range) — batched edge-list MSTs
  hierarchy, dbcv     — extraction & validation submodules
"""

from . import boruvka, dbcv, hierarchy, mrd, rng, sbcn, wspd
from .boruvka import boruvka_mst, boruvka_mst_range, prim_dense_mst
from .mrd import core_distances2, edge_mrd2, mrd2_from_parts, reweight_all_mpts
from .multi import HierarchyResult, MultiDensityResult, hdbscan_baseline, multi_hdbscan
from .rng import RngGraph, build_rng_graph

__all__ = [
    "boruvka", "dbcv", "hierarchy", "mrd", "rng", "sbcn", "wspd",
    "boruvka_mst", "boruvka_mst_range", "prim_dense_mst",
    "core_distances2", "edge_mrd2", "mrd2_from_parts", "reweight_all_mpts",
    "HierarchyResult", "MultiDensityResult", "hdbscan_baseline", "multi_hdbscan",
    "RngGraph", "build_rng_graph",
]
