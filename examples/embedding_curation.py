"""End-to-end driver: train an LM, embed a corpus, explore it with the
multi-density engine, pick a density level by DBCV, emit curation decisions.

This is the production use-case that motivates shipping the paper's engine
inside an LM framework (DESIGN.md §4): embedding-space analysis — semantic
dedup / outlier removal — needs clusterings at MANY density levels, and the
engine provides all of them for ~the cost of two.

  PYTHONPATH=src python examples/embedding_curation.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import dbcv, multi
from repro.launch.train import main as train_main
from repro.models import get_model, init_params
from repro.train import data as data_lib


def main():
    # 1) train a small LM briefly (real train loop, synthetic corpus)
    print("=== step 1: train a reduced LM for 15 steps ===")
    train_main([
        "--arch", "qwen2_1_5b", "--reduced", "--steps", "15",
        "--global-batch", "4", "--seq-len", "64", "--lr", "3e-3",
    ])

    # 2) embed a "corpus" with the LM (mean-pooled hidden states)
    print("\n=== step 2: embed 1200 documents ===")
    cfg = get_config("qwen2_1_5b").reduced()
    model = get_model(cfg)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    dcfg = data_lib.DataConfig(seed=9, vocab=cfg.vocab, seq_len=48, global_batch=8)

    @jax.jit
    def embed(params, tokens):
        h, _ = model.forward(params, cfg, tokens)
        return jnp.mean(h, axis=1)

    embs = []
    for step in range(150):
        batch = data_lib.train_batch(dcfg, step)
        embs.append(np.asarray(embed(params, batch["tokens"])))
    x = np.concatenate(embs).astype(np.float32)
    # inject duplicated docs (the dedup targets)
    x[-40:] = x[:40] + np.random.default_rng(0).normal(0, 1e-3, x[:40].shape)
    print(f"embeddings: {x.shape}")

    # 3) multi-density exploration
    print("\n=== step 3: all hierarchies for mpts in [2, 24] ===")
    res = multi.multi_hdbscan(x, 24, variant="rng_star")
    scores = {}
    for h in res.hierarchies:
        scores[h.mpts] = dbcv.dbcv_relative_validity(h.mst_ea, h.mst_eb, h.mst_w, h.labels)
    best = max(scores, key=lambda k: scores[k])
    print("DBCV by mpts (sampled):",
          {k: round(v, 3) for k, v in list(scores.items())[::4]})
    print(f"selected density level: mpts={best} (DBCV={scores[best]:.3f})")

    # 4) curation decisions at the chosen level
    h = [hh for hh in res.hierarchies if hh.mpts == best][0]
    n_noise = int((h.labels == -1).sum())
    sizes = np.bincount(h.labels[h.labels >= 0]) if h.n_clusters else []
    print(f"\n=== step 4: curation report ===")
    print(f"clusters: {h.n_clusters}, outliers flagged: {n_noise}")
    # near-duplicate detection: tiny-mrd MST edges = candidate dupes
    thresh = np.quantile(h.mst_w, 0.01)
    dup_edges = h.mst_w < max(thresh, 1e-6)
    print(f"near-duplicate pairs (bottom-1% mrd): {int(dup_edges.sum())} "
          f"(injected 40 dupes)")
    keep = np.ones(len(x), bool)
    keep[h.mst_eb[dup_edges]] = False
    print(f"keep list: {int(keep.sum())}/{len(x)} documents")


if __name__ == "__main__":
    main()
