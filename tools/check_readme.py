"""Docs sanity check: README python blocks must parse, and the ones that
exercise the public API must actually run.  Also guards the
BENCH_pipeline.json schema: perf-trajectory tooling diffs that file across
commits, so a benchmark edit that silently drops a field (provenance, the
serve section) must fail CI here, not corrupt the trajectory later.

Every ```python fenced block in README.md is compiled; blocks that import
only from the public surface (repro, numpy) are executed in a shared
namespace so the quickstart is guaranteed to work as printed.

  PYTHONPATH=src python tools/check_readme.py
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

# append-only field contract (see benchmarks/run.py::pipeline_bench): a key
# may be ADDED with a schema_version bump, never renamed or removed
BENCH_REQUIRED_FIELDS = [
    "schema_version",
    "config.n", "config.d", "config.kmax", "config.backend", "config.plan",
    "provenance.git_sha", "provenance.config_hash", "provenance.warm_reps",
    "multi.knn", "multi.rng_build", "multi.mst_range", "multi.hierarchy",
    "multi.total",
    "baseline.knn", "baseline.mst", "baseline.hierarchy", "baseline.total",
    "cold.multi_total", "cold.baseline_total",
    "edges.rng", "edges.complete",
    "speedup_vs_baseline",
    "serve.batch", "serve.n_queries", "serve.p50_ms", "serve.p95_ms",
    "serve.queries_per_s", "serve.mean_batch",
    "artifact.save_ms", "artifact.load_ms", "artifact.bytes",
    "nscale.sizes", "nscale.d", "nscale.kmax", "nscale.rows",
    "nscale.slope_candidates",
]


def blocks(md: str) -> list[str]:
    return re.findall(r"```python\n(.*?)```", md, flags=re.DOTALL)


def check_bench_schema(path: Path) -> list[str]:
    """Missing-field paths of the tracked benchmark file (empty = ok)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name} unreadable: {e}"]
    missing = []
    for dotted in BENCH_REQUIRED_FIELDS:
        node = doc
        for part in dotted.split("."):
            if not isinstance(node, dict) or part not in node:
                missing.append(dotted)
                break
            node = node[part]
    ns = doc.get("nscale")
    if isinstance(ns, dict) and 100000 not in (ns.get("sizes") or []):
        missing.append("nscale.sizes: 100000 (the routine large-n row)")
    return missing


def main() -> int:
    md = (ROOT / "README.md").read_text()
    found = blocks(md)
    if not found:
        print("FAIL: README.md has no ```python blocks")
        return 1

    ns: dict = {}
    n_run = 0
    for i, src in enumerate(found):
        try:
            code = compile(src, f"README.md[block {i}]", "exec")
        except SyntaxError as e:
            print(f"FAIL: README block {i} does not parse: {e}")
            return 1
        try:
            exec(code, ns)  # noqa: S102 - the point is to run the docs
            n_run += 1
        except Exception as e:  # noqa: BLE001
            print(f"FAIL: README block {i} raised {type(e).__name__}: {e}")
            return 1

    missing = check_bench_schema(ROOT / "BENCH_pipeline.json")
    if missing:
        print(
            "FAIL: BENCH_pipeline.json lost schema fields "
            f"(append-only contract): {missing}"
        )
        return 1

    import repro
    import repro.api  # noqa: F401  (public surface must import)

    print(f"ok: {len(found)} README blocks parsed, {n_run} executed; "
          f"repro {repro.__version__} imports; BENCH_pipeline.json schema ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
