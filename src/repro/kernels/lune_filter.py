"""Pallas TPU kernel: blocked lune-emptiness test for exact-RNG filtering.

Paper §IV-E, Algorithm 1 lines 22-26: an edge ``(a, b)`` with mutual-
reachability weight ``w = mrd_kmax(a, b)`` survives into the exact RNG iff no
point ``c`` lies strictly inside ``lune(a, b)``:

    inside(c)  <=>  max( mrd(a, c), mrd(b, c) ) < w ,   c not in {a, b}

with ``mrd(x, c) = max( d(x, c), cd_kmax(x), cd_kmax(c) )``.

The paper scans the dataset per unresolved edge; the TPU adaptation processes
an (edge-tile x point-tile) block per grid step: two MXU dot products give
``d2(a, c)`` and ``d2(b, c)`` for the whole tile, the VPU applies the max
cascade, and a per-edge OR is accumulated into a revisited output block.
Everything is in *squared* space (max and comparisons commute with sqrt).

Working set per step: (be, d) a/b point tiles, (bc, d) candidate tile,
2 x (be, bc) distance tiles — tiled for VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .compat import COMPILER_PARAMS as _COMPILER_PARAMS



def _lune_filter_kernel(
    ax_ref,     # (be, d)  edge endpoint a coordinates
    bx_ref,     # (be, d)  edge endpoint b coordinates
    acd_ref,    # (be, 1)  cd2_kmax(a)
    bcd_ref,    # (be, 1)  cd2_kmax(b)
    aidx_ref,   # (be, 1)  global index of a
    bidx_ref,   # (be, 1)  global index of b
    w_ref,      # (be, 1)  squared mrd_kmax edge weight
    c_ref,      # (bc, d)  candidate point tile
    ccd_ref,    # (bc, 1)  cd2_kmax(c)
    out_ref,    # (be, 1)  int32: 1 if some c is inside lune(a, b)
    *,
    block_e: int,
    block_c: int,
    n_total: int,
):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        out_ref[...] = jnp.zeros((block_e, 1), jnp.int32)

    a = ax_ref[...].astype(jnp.float32)
    b = bx_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    cn = jnp.sum(c * c, axis=-1, keepdims=True).T                       # (1, bc)
    d2_ac = jnp.sum(a * a, -1, keepdims=True) + cn - 2.0 * jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2_bc = jnp.sum(b * b, -1, keepdims=True) + cn - 2.0 * jax.lax.dot_general(
        b, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2_ac = jnp.maximum(d2_ac, 0.0)
    d2_bc = jnp.maximum(d2_bc, 0.0)

    ccd = ccd_ref[...].T                                                # (1, bc)
    mrd_ac = jnp.maximum(jnp.maximum(d2_ac, acd_ref[...]), ccd)
    mrd_bc = jnp.maximum(jnp.maximum(d2_bc, bcd_ref[...]), ccd)

    # Cancellation guard: the |a|^2+|c|^2-2ac form can err by O(eps * norms);
    # a point only counts as inside the lune if it beats that margin, so
    # numeric noise can only ADD edges (safe: keeps the RNG a superset).
    eps = jnp.float32(64.0 * 1.1920929e-07)
    an = jnp.sum(a * a, -1, keepdims=True)
    bn = jnp.sum(b * b, -1, keepdims=True)
    margin_ac = eps * (an + cn)
    margin_bc = eps * (bn + cn)

    col_g = cj * block_c + jax.lax.broadcasted_iota(jnp.int32, (block_e, block_c), 1)
    is_endpoint = (col_g == aidx_ref[...]) | (col_g == bidx_ref[...])
    padded = col_g >= n_total

    inside = (
        (jnp.maximum(mrd_ac + margin_ac, mrd_bc + margin_bc) < w_ref[...])
        & ~is_endpoint
        & ~padded
    )
    any_inside = jnp.any(inside, axis=1, keepdims=True).astype(jnp.int32)
    out_ref[...] = out_ref[...] | any_inside


def lune_filter(
    a_xyz: jax.Array,   # (m, d) coordinates of edge endpoint a
    b_xyz: jax.Array,   # (m, d) coordinates of edge endpoint b
    a_cd2: jax.Array,   # (m,)   squared core distance of a (at kmax)
    b_cd2: jax.Array,   # (m,)   squared core distance of b (at kmax)
    a_idx: jax.Array,   # (m,)   global point index of a
    b_idx: jax.Array,   # (m,)   global point index of b
    w2: jax.Array,      # (m,)   squared mrd_kmax edge weight
    points: jax.Array,  # (n, d) full dataset
    cd2: jax.Array,     # (n,)   squared core distances of all points (at kmax)
    *,
    block_e: int = 256,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns a boolean (m,) mask: True where the lune is NON-empty (remove edge)."""
    m, d = a_xyz.shape
    n = points.shape[0]
    block_e = min(block_e, max(8, m))
    block_c = min(block_c, max(8, n))

    m_pad = -(-m // block_e) * block_e
    n_pad = -(-n // block_c) * block_c

    def padm(x, fill=0):
        return jnp.full((m_pad,) + x.shape[1:], fill, x.dtype).at[:m].set(x)

    ax = padm(a_xyz)
    bx = padm(b_xyz)
    acd = padm(a_cd2)[:, None]
    bcd = padm(b_cd2)[:, None]
    ai = padm(a_idx.astype(jnp.int32), -1)[:, None]
    bi = padm(b_idx.astype(jnp.int32), -1)[:, None]
    # Padded edges get w2 = -inf so nothing can ever be "inside" their lune.
    w = jnp.full((m_pad,), -jnp.inf, jnp.float32).at[:m].set(w2.astype(jnp.float32))[:, None]
    pts = jnp.zeros((n_pad, d), points.dtype).at[:n].set(points)
    pcd = jnp.zeros((n_pad,), jnp.float32).at[:n].set(cd2.astype(jnp.float32))[:, None]

    grid = (m_pad // block_e, n_pad // block_c)
    kernel = functools.partial(
        _lune_filter_kernel, block_e=block_e, block_c=block_c, n_total=n
    )
    e_spec = lambda blk: pl.BlockSpec(blk, lambda i, j: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            e_spec((block_e, d)),
            e_spec((block_e, d)),
            e_spec((block_e, 1)),
            e_spec((block_e, 1)),
            e_spec((block_e, 1)),
            e_spec((block_e, 1)),
            e_spec((block_e, 1)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_c, 1), lambda i, j: (j, 0)),
        ],
        out_specs=e_spec((block_e, 1)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ax, bx, acd, bcd, ai, bi, w, pts, pcd)
    return out[:m, 0].astype(bool)
