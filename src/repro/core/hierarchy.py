"""HDBSCAN* hierarchy extraction: dendrogram -> condensed tree -> clusters.

Host-side post-processing (numpy): consumes the (n-1)-edge MST produced on
device and is O(n alpha(n)) scalar work (DESIGN.md §3).  Implements the
standard HDBSCAN* machinery (Campello et al. 2013/2015):

  * ``single_linkage``  — scipy-style merge matrix Z via union-find over
    weight-sorted MST edges.
  * ``condense_tree``   — collapse the dendrogram w.r.t. ``min_cluster_size``:
    a node is a *true split* iff both children have >= mcs points; otherwise
    points "fall out" of the surviving cluster at that lambda = 1/distance.
  * ``compute_stability`` / ``extract_clusters`` — excess-of-mass (FOSC)
    selection, bottom-up.
  * ``labels_for``      — final labels (-1 = noise) + per-point lambdas.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def single_linkage(ea: np.ndarray, eb: np.ndarray, w: np.ndarray, n: int) -> np.ndarray:
    """Union-find single linkage. Returns Z (n-1, 4): left, right, dist, size.

    Cluster ids: 0..n-1 are points; n+i is the cluster formed by row i.
    Edges must form a spanning tree; `w` are (non-squared) distances.
    """
    order = np.lexsort((np.arange(len(w)), w))
    parent = np.arange(2 * n - 1, dtype=np.int64)
    uf_label = np.arange(n, dtype=np.int64)  # current cluster label of each root
    size = np.ones(2 * n - 1, dtype=np.int64)

    def find(v):
        root = v
        while parent[root] != root:
            root = parent[root]
        while parent[v] != root:  # path compression
            parent[v], v = root, parent[v]
        return root

    Z = np.zeros((n - 1, 4), np.float64)
    nxt = 0
    for ei in order:
        ra, rb = find(ea[ei]), find(eb[ei])
        if ra == rb:
            continue
        la, lb = uf_label[ra], uf_label[rb]
        new = n + nxt
        merged = size[la] + size[lb]
        Z[nxt] = (la, lb, w[ei], merged)
        size[new] = merged
        # merge union-find roots
        parent[ra] = rb
        uf_label[rb] = new
        nxt += 1
    if nxt != n - 1:
        raise ValueError(f"edge list does not span: {nxt + 1} components remain")
    return Z


@dataclasses.dataclass
class CondensedTree:
    parent: np.ndarray      # (k,) condensed parent cluster id (>= n)
    child: np.ndarray       # (k,) point id (< n) or child cluster id (>= n)
    lam: np.ndarray         # (k,) lambda = 1/dist at which child leaves parent
    child_size: np.ndarray  # (k,)
    n_points: int
    root: int               # root cluster id (== n_points)


def condense_tree(Z: np.ndarray, n: int, min_cluster_size: int) -> CondensedTree:
    """Condense a single-linkage dendrogram (hdbscan-style, iterative BFS)."""
    root = 2 * n - 2  # top merge (dendrogram id n + (n-2))
    next_label = n + 1
    relabel = {root: n}

    parents: list[int] = []
    children: list[int] = []
    lams: list[float] = []
    sizes: list[int] = []

    def node_info(node):
        """(left, right, dist, size) for dendrogram node id; points -> leaf."""
        row = Z[node - n]
        return int(row[0]), int(row[1]), float(row[2]), int(row[3])

    def node_size(node):
        return 1 if node < n else int(Z[node - n][3])

    def leaves_of(node):
        out = []
        stack = [node]
        while stack:
            v = stack.pop()
            if v < n:
                out.append(v)
            else:
                l, r, _, _ = node_info(v)
                stack.extend((l, r))
        return out

    ignore = set()
    # BFS top-down over dendrogram nodes that still carry a cluster label.
    stack = [root]
    while stack:
        node = stack.pop()
        if node in ignore or node < n:
            continue
        cur_label = relabel[node]
        left, right, dist, _ = node_info(node)
        lam = 1.0 / dist if dist > 0.0 else np.inf
        ls, rs = node_size(left), node_size(right)

        if ls >= min_cluster_size and rs >= min_cluster_size:
            for ch, s in ((left, ls), (right, rs)):
                relabel[ch] = next_label
                parents.append(cur_label)
                children.append(next_label)
                lams.append(lam)
                sizes.append(s)
                next_label += 1
                stack.append(ch)
        else:
            for ch, s in ((left, ls), (right, rs)):
                if s >= min_cluster_size:
                    relabel[ch] = cur_label  # cluster continues under same label
                    stack.append(ch)
                else:
                    for p in leaves_of(ch):  # points fall out at this lambda
                        parents.append(cur_label)
                        children.append(p)
                        lams.append(lam)
                        sizes.append(1)
                    ignore.add(ch)

    return CondensedTree(
        parent=np.asarray(parents, np.int64),
        child=np.asarray(children, np.int64),
        lam=np.asarray(lams, np.float64),
        child_size=np.asarray(sizes, np.int64),
        n_points=n,
        root=n,
    )


def compute_stability(tree: CondensedTree) -> dict[int, float]:
    """Excess-of-mass stability: sum_p (lambda_p - lambda_birth(C))."""
    lam_birth: dict[int, float] = {tree.root: 0.0}
    cluster_rows = tree.child >= tree.n_points
    for p, c, l in zip(
        tree.parent[cluster_rows], tree.child[cluster_rows], tree.lam[cluster_rows]
    ):
        lam_birth[int(c)] = float(l)

    stability: dict[int, float] = {c: 0.0 for c in lam_birth}
    finite_cap = np.max(tree.lam[np.isfinite(tree.lam)], initial=1.0)
    for p, l, s in zip(tree.parent, tree.lam, tree.child_size):
        lv = float(l) if np.isfinite(l) else float(finite_cap)
        stability[int(p)] = stability.get(int(p), 0.0) + (lv - lam_birth[int(p)]) * int(s)
    return stability


def extract_clusters(
    tree: CondensedTree,
    stability: dict[int, float],
    *,
    allow_single_cluster: bool = False,
) -> list[int]:
    """FOSC bottom-up selection; returns selected condensed cluster ids."""
    children_of: dict[int, list[int]] = {}
    cluster_rows = tree.child >= tree.n_points
    for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows]):
        children_of.setdefault(int(p), []).append(int(c))

    clusters = sorted(stability.keys(), reverse=True)  # children have larger ids
    selected = {c: True for c in clusters}
    subtree_val = dict(stability)
    for c in clusters:
        kids = children_of.get(c, [])
        if not kids:
            continue
        kid_sum = sum(subtree_val[k] for k in kids)
        if kid_sum > stability[c] or (c == tree.root and not allow_single_cluster):
            selected[c] = False
            subtree_val[c] = kid_sum
        else:
            # select c; deselect entire subtree below
            stack = list(kids)
            while stack:
                k = stack.pop()
                selected[k] = False
                stack.extend(children_of.get(k, []))
    if not allow_single_cluster:
        selected[tree.root] = False
    return [c for c in clusters if selected[c]]


def labels_for(tree: CondensedTree, selected: list[int]) -> tuple[np.ndarray, np.ndarray]:
    """Per-point labels (-1 noise) and the lambda at which each point departs."""
    n = tree.n_points
    labels = np.full(n, -1, np.int64)
    lam_pt = np.zeros(n, np.float64)

    sel = set(selected)
    # map each condensed cluster to its selected ancestor (or -1)
    parent_of: dict[int, int] = {}
    cluster_rows = tree.child >= n
    for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows]):
        parent_of[int(c)] = int(p)

    def selected_ancestor(c: int) -> int:
        while True:
            if c in sel:
                return c
            if c not in parent_of:
                return -1
            c = parent_of[c]

    cache: dict[int, int] = {}
    point_rows = ~cluster_rows
    label_ids = {c: i for i, c in enumerate(sorted(sel))}
    for p, c, l in zip(
        tree.parent[point_rows], tree.child[point_rows], tree.lam[point_rows]
    ):
        p = int(p)
        if p not in cache:
            cache[p] = selected_ancestor(p)
        anc = cache[p]
        if anc != -1:
            labels[int(c)] = label_ids[anc]
            lam_pt[int(c)] = l
    return labels, lam_pt


def hdbscan_labels(
    ea: np.ndarray,
    eb: np.ndarray,
    w: np.ndarray,
    n: int,
    min_cluster_size: int,
    *,
    allow_single_cluster: bool = False,
) -> tuple[np.ndarray, CondensedTree, dict[int, float]]:
    """MST edges -> (labels, condensed tree, stability). `w` = real distances."""
    Z = single_linkage(ea, eb, w, n)
    tree = condense_tree(Z, n, min_cluster_size)
    stability = compute_stability(tree)
    selected = extract_clusters(tree, stability, allow_single_cluster=allow_single_cluster)
    labels, _ = labels_for(tree, selected)
    return labels, tree, stability
