"""Hierarchy extraction: single-linkage vs scipy, condensed-tree semantics,
full-pipeline label equivalence (RNG path vs dense-matrix path)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.cluster.hierarchy import linkage

from repro.core import hierarchy, multi, ref as oref


@st.composite
def spanning_edges(draw):
    n = draw(st.integers(5, 60))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    ea = np.arange(n - 1)
    eb = np.array([rng.integers(i + 1, n) if i + 1 < n else n - 1 for i in range(n - 1)])
    # random spanning tree: connect each node to a random earlier node
    ea = np.array([rng.integers(0, i + 1) for i in range(n - 1)])
    eb = np.arange(1, n)
    w = rng.uniform(0.1, 5.0, size=n - 1)
    return n, ea, eb, w


@given(spanning_edges())
@settings(max_examples=30, deadline=None)
def test_single_linkage_matches_scipy(t):
    n, ea, eb, w = t
    Z = hierarchy.single_linkage(ea, eb, w, n)
    # scipy needs a dense distance matrix consistent with the tree's metric:
    # use the path-max distance implied by the MST (single-linkage ultrametric)
    # instead just compare merge heights + sizes against scipy on the mst
    # edge list converted to dense graph shortest-max-path: simpler check —
    # merge DISTANCES multiset must equal edge weights, sizes must telescope.
    np.testing.assert_allclose(np.sort(Z[:, 2]), np.sort(w))
    assert Z[-1, 3] == n
    assert (Z[:, 3] >= 2).all()


def test_single_linkage_vs_scipy_dense(gauss16d):
    x = gauss16d[:100].astype(np.float64)
    m = oref.mrd_matrix(x, 4)
    ea, eb, w = oref.mst_edges_dense(m)
    Z_ours = hierarchy.single_linkage(ea, eb, w, len(x))
    # scipy single linkage on the mrd matrix (condensed form)
    from scipy.spatial.distance import squareform
    Z_scipy = linkage(squareform(m, checks=False), method="single")
    np.testing.assert_allclose(np.sort(Z_ours[:, 2]), np.sort(Z_scipy[:, 2]), rtol=1e-9)
    # mrd ties are frequent; tied merges may interleave differently between
    # implementations (both trees valid).  Sizes must match where heights are
    # unique, and always at the top.
    order_o = np.argsort(Z_ours[:, 2], kind="stable")
    h_sorted = Z_ours[order_o, 2]
    uniq = np.concatenate([[True], np.diff(h_sorted) > 1e-12]) & np.concatenate(
        [np.diff(h_sorted) > 1e-12, [True]]
    )
    sizes_o = Z_ours[order_o, 3][uniq]
    sizes_s = Z_scipy[np.argsort(Z_scipy[:, 2], kind="stable"), 3][uniq]
    np.testing.assert_allclose(sizes_o, sizes_s)
    assert Z_ours[-1, 3] == Z_scipy[-1, 3] == len(x)


def test_condensed_tree_blobs(blobs):
    x, gt = blobs
    res = multi.multi_hdbscan(x, 12, variant="rng_star")
    h = [hh for hh in res.hierarchies if hh.mpts == 6][0]
    assert h.n_clusters == 3
    # each true blob maps to exactly one predicted cluster (majority)
    for blob_id, size in ((0, 80), (1, 80), (2, 60)):
        labs = h.labels[gt == blob_id]
        labs = labs[labs >= 0]
        vals, counts = np.unique(labs, return_counts=True)
        assert counts.max() / size > 0.9


def test_full_pipeline_equals_dense_pipeline(blobs):
    """Same extraction code fed by (a) the RNG MST and (b) the dense-matrix
    MST must produce identical labels (Cor. 1 at the *label* level)."""
    x, _ = blobs
    kmax = 10
    res = multi.multi_hdbscan(x, kmax, variant="rng")
    cd = oref.core_distances(x.astype(np.float64), kmax)
    for h in res.hierarchies[::3]:
        m = oref.mrd_matrix(x.astype(np.float64), h.mpts, cd)
        ea, eb, w = oref.mst_edges_dense(m)
        labels_dense, _, _ = hierarchy.hdbscan_labels(
            ea, eb, w, len(x), max(2, h.mpts)
        )
        # label ids may permute; compare partitions via contingency
        a, b = h.labels, labels_dense
        assert (a >= 0).sum() == (b >= 0).sum()
        for ca in np.unique(a[a >= 0]):
            members = b[a == ca]
            vals, counts = np.unique(members, return_counts=True)
            assert counts.max() / counts.sum() > 0.99


def test_stability_monotone_selection(blobs):
    x, _ = blobs
    res = multi.multi_hdbscan(x, 8, variant="rng_star")
    h = res.hierarchies[-1]
    stab = h.stability
    assert all(v >= 0 or np.isinf(v) for v in stab.values())
