"""Dense Prim vs scipy oracle, batched-Borůvka parity, and the disconnected
edge-list error path.  (Property-based Boruvka checks live in
test_mst_property.py and need hypothesis.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boruvka, multi, ref as oref


def test_prim_dense_matches_scipy(gauss16d):
    x = gauss16d[:150]
    cd = oref.core_distances(x.astype(np.float64), 6)
    m = oref.mrd_matrix(x.astype(np.float64), 6, cd)
    src, w2 = boruvka.prim_dense_mst(
        jnp.asarray(x), jnp.asarray((cd[:, 5] ** 2).astype(np.float32))
    )
    got = np.sort(np.sqrt(np.asarray(w2)[1:]))
    np.testing.assert_allclose(got, oref.mst_weights(m), rtol=1e-5, atol=1e-6)


def test_batched_range_matches_single_row_boruvka():
    """The natively-batched rank-key range is bit-identical to the two-phase
    single-row Borůvka — including under heavy weight ties and zeros."""
    rng = np.random.default_rng(4)
    n, m, R = 90, 400, 9
    ea = rng.integers(0, n, size=m).astype(np.int32)
    eb = (ea + 1 + rng.integers(0, n - 1, size=m).astype(np.int32)) % n
    ea_j = jnp.concatenate([jnp.asarray(ea), jnp.arange(n - 1, dtype=jnp.int32)])
    eb_j = jnp.concatenate([jnp.asarray(eb), jnp.arange(1, n, dtype=jnp.int32)])
    w = jnp.asarray(np.concatenate(
        [rng.choice([0.0, 0.25, 0.5, 1.0], size=(R, m)),
         np.full((R, n - 1), 3.0)], axis=1
    ).astype(np.float32))
    got = np.asarray(boruvka.boruvka_mst_range(ea_j, eb_j, w, n=n))
    want = np.asarray(
        jax.vmap(lambda wr: boruvka.boruvka_mst(ea_j, eb_j, wr, n=n))(w)
    )
    assert (got == want).all()
    assert (got.sum(axis=1) == n - 1).all()


def test_disconnected_edge_list_returns_partial_mst():
    """boruvka_mst on a disconnected edge list exits via progressed=False
    with < n-1 edges (the condition fit_msts turns into a hard error)."""
    ea = jnp.asarray([0, 1, 3, 4], jnp.int32)   # {0,1,2} and {3,4,5} islands
    eb = jnp.asarray([1, 2, 4, 5], jnp.int32)
    w = jnp.ones((4,), jnp.float32)
    in_mst = np.asarray(boruvka.boruvka_mst(ea, eb, w, n=6))
    assert in_mst.sum() == 4 < 5
    in_mst_r = np.asarray(
        boruvka.boruvka_mst_range(ea, eb, jnp.ones((3, 4), jnp.float32), n=6)
    )
    assert (in_mst_r.sum(axis=1) == 4).all()


def test_fit_msts_raises_on_disconnected_graph(blobs, monkeypatch):
    """Regression: a disconnected RNG (upstream filter bug) must fail loudly
    in fit_msts instead of feeding garbage rows into linkage."""
    from repro.core import rng as rng_mod

    x, _ = blobs
    real_build = rng_mod.build_rng_graph

    def broken_build(*args, **kwargs):
        g = real_build(*args, **kwargs)
        # sever the graph: drop every edge touching the first 30 points
        keep = (g.edges[:, 0] >= 30) & (g.edges[:, 1] >= 30)
        return rng_mod.RngGraph(
            edges=g.edges[keep], d2=g.d2[keep], w2_kmax=g.w2_kmax[keep],
            variant=g.variant, n_points=g.n_points, stats=g.stats,
        )

    monkeypatch.setattr(multi, "build_rng_graph", broken_build)
    with pytest.raises(RuntimeError, match="MST incomplete.*disconnected"):
        multi.fit_msts(x, 6)
