"""Public estimator API for the multi-density clustering engine.

    from repro.api import MultiHDBSCAN

    est = MultiHDBSCAN(kmax=32).fit(x)
    labels = est.labels_for(mpts=8)        # lazily extracted, cached
    tree = est.hierarchy_for(mpts=8)       # condensed tree + stabilities
    profile = est.mpts_profile()           # the whole density range at a glance
"""

from .estimator import MultiHDBSCAN

__all__ = ["MultiHDBSCAN"]
