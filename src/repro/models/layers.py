"""Shared model layers, pure-function style (params = plain pytrees).

Conventions:
  * every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
    params pytree with a tuple of *logical axis names* per array dimension —
    the sharding-rule system (dist/sharding.py) maps those to mesh axes.
  * compute dtype comes from the input; accumulation is f32 where it matters
    (attention softmax, losses, routing).
  * attention is the double-chunked online-softmax form (flash-style in pure
    JAX): memory O(chunk^2) regardless of sequence length, which is what lets
    prefill_32k lower without an S x S score tensor.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, specs, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale, specs


def rmsnorm_init(d):
    return jnp.zeros((d,), jnp.float32), ("embed",)


def rmsnorm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta, rotary_dim=None):
    """x: (..., S, H, D); positions: (..., S) int32. Applies RoPE in f32."""
    d = rotary_dim or x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half)
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:d].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2, x[..., d:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (double-chunked online softmax; GQA; window; softcap)
# ---------------------------------------------------------------------------


@functools.partial(jax.checkpoint, static_argnums=(6,))
def _attn_inner(q, k, v, q_pos, k_pos, window, softcap, kv_valid=None):
    # NOTE the jax.checkpoint: the flash-style invariant.  The (Sq x Sk)
    # score/prob tiles are NOT saved for the backward pass — they are
    # recomputed from (q, k, v, m, l), so attention memory stays O(tile)
    # under autodiff instead of O(S^2) (the 229 GiB/device failure mode the
    # first dry-run exposed).
    """One (q-chunk x kv-chunk) tile. q: (B, Sq, Hq, D) k/v: (B, Sk, Hkv, D)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    diff = q_pos[:, None] - k_pos[None, :]                     # (Sq, Sk)
    mask = diff >= 0
    mask = mask & (diff < window)
    if kv_valid is not None:
        mask = mask & kv_valid[None, :]
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                     # (b,h,g,q)
    # guard fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o, m_safe, l, jnp.isfinite(m)


def attention(
    q,
    k,
    v,
    *,
    q_pos,
    k_pos,
    window: jax.Array | int | None = None,
    softcap: float = 0.0,
    kv_valid=None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    """Causal (optionally windowed) GQA attention, chunked both ways.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); q_pos: (Sq,), k_pos: (Sk,).
    ``window`` may be a traced scalar (per-layer mixed local/global stacks
    scan over it); ``window <= 0`` means unbounded (full causal).
    kv_valid: optional (Sk,) bool (cache slots already written).
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    window = jnp.asarray(window if window is not None else 0, jnp.int32)
    window = jnp.where(window <= 0, jnp.int32(2**30), window)

    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_seq", None, None))
    v = constrain(v, ("act_batch", "act_seq", None, None))
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    # pad to chunk multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - sq), (0, 0), (0, 0)))
    qpp = jnp.pad(q_pos, (0, nq * q_chunk - sq))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - sk), (0, 0), (0, 0)))
    kpp = jnp.pad(k_pos, (0, nk * kv_chunk - sk), constant_values=2**30)
    valid = kv_valid if kv_valid is not None else jnp.ones((sk,), bool)
    validp = jnp.pad(valid, (0, nk * kv_chunk - sk))

    def q_block(qi):
        qc = jax.lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, axis=1)
        qpos_c = jax.lax.dynamic_slice_in_dim(qpp, qi * q_chunk, q_chunk)

        def kv_step(carry, kj):
            acc, m_run, l_run = carry
            kc = jax.lax.dynamic_slice_in_dim(kp, kj * kv_chunk, kv_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, kj * kv_chunk, kv_chunk, axis=1)
            kpos_c = jax.lax.dynamic_slice_in_dim(kpp, kj * kv_chunk, kv_chunk)
            val_c = jax.lax.dynamic_slice_in_dim(validp, kj * kv_chunk, kv_chunk)
            o, m, l, any_valid = _attn_inner(
                qc, kc, vc, qpos_c, kpos_c, window, softcap, val_c
            )
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)
            beta = jnp.where(any_valid, jnp.exp(m - m_new), 0.0)
            acc = acc * alpha[..., None] + o * beta[..., None]
            l_new = l_run * alpha + l * beta
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, hkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        # (b, hkv, g, qc, d) -> (b, qc, hq, d)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, dh)

    out = jax.lax.map(q_block, jnp.arange(nq))                  # (nq, b, qc, hq, d)
    out = constrain(out, (None, "act_batch", "act_seq", "act_heads", None))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, hq, dh)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p, s = {}, {}
    if cfg.act in ("swiglu", "geglu"):
        p["wi"], s["wi"] = dense_init(ks[0], (d, 2 * d_ff), ("embed", "ff2"), jnp.float32)
        p["wo"], s["wo"] = dense_init(ks[1], (d_ff, d), ("ff", "embed"), jnp.float32)
    else:
        p["wi"], s["wi"] = dense_init(ks[0], (d, d_ff), ("embed", "ff"), jnp.float32)
        p["wo"], s["wo"] = dense_init(ks[1], (d_ff, d), ("ff", "embed"), jnp.float32)
        if cfg.mlp_bias:
            p["bi"], s["bi"] = jnp.zeros((d_ff,), jnp.float32), ("ff",)
            p["bo"], s["bo"] = jnp.zeros((d,), jnp.float32), ("embed",)
    return p, s


def mlp(p, x, cfg, d_ff):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        h = constrain(x @ p["wi"].astype(dt), ("act_batch", "act_seq", "act_ff"))
        u, g = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu if cfg.act == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True
        )
        return (act(g) * u) @ p["wo"].astype(dt)
    h = constrain(x @ p["wi"].astype(dt), ("act_batch", "act_seq", "act_ff"))
    if cfg.mlp_bias:
        h = h + p["bi"].astype(dt)
    h = jax.nn.gelu(h, approximate=True)
    o = h @ p["wo"].astype(dt)
    if cfg.mlp_bias:
        o = o + p["bo"].astype(dt)
    return o


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k routing, per-expert top-C capacity, EP-shardable)
# ---------------------------------------------------------------------------


def init_moe(key, cfg):
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], (d, e), ("embed", "experts"), jnp.float32)
    p["wi"], s["wi"] = dense_init(ks[1], (e, d, 2 * f), ("experts", "embed", "ff2"), jnp.float32)
    p["wo"], s["wo"] = dense_init(ks[2], (e, f, d), ("experts", "ff", "embed"), jnp.float32)
    if cfg.n_shared:
        fs = cfg.d_ff_expert * cfg.n_shared
        p["shared_wi"], s["shared_wi"] = dense_init(ks[3], (d, 2 * fs), ("embed", "ff2"), jnp.float32)
        p["shared_wo"], s["shared_wo"] = dense_init(ks[4], (fs, d), ("ff", "embed"), jnp.float32)
    return p, s


def moe(p, x, cfg):
    """x: (B, S, D) -> (B, S, D); returns (out, aux_loss).

    Dispatch: per-expert top-C token selection among each token's top-k
    experts (capacity-bounded, drop-on-overflow — GShard-style), realized as
    gathers + one batched expert einsum + scatter-add combine.  Experts shard
    over the 'model' mesh axis (expert parallelism); the scatter-add back to
    the token stream is the EP combine collective under GSPMD.
    """
    b, s_len, d = x.shape
    dt = x.dtype
    t = b * s_len
    xf = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                     # (T, E) f32
    topv, _ = jax.lax.top_k(gates, k)
    keep = gates >= topv[:, -1:]
    gk = jnp.where(keep, gates, 0.0)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(keep.astype(jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * e * cfg.router_aux_weight

    cap = int(max(1, math.ceil(t * k * cfg.capacity_factor / e)))
    cap = min(cap, t)
    gsel, idx = jax.lax.top_k(gk.T, cap)                        # (E, C)
    xe = constrain(xf[idx], ("act_experts", None, "act_embed"))  # (E, C, D)
    h = constrain(
        jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt)),
        ("act_experts", None, None),
    )
    u, g = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    y = constrain(
        jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt)),
        ("act_experts", None, "act_embed"),
    )
    y = y * gsel[..., None].astype(dt)
    out = jnp.zeros((t, d), dt).at[idx.reshape(-1)].add(
        y.reshape(-1, d), mode="drop"
    )
    out = constrain(out, ("act_batch", "act_embed"))

    if cfg.n_shared:
        hs = xf @ p["shared_wi"].astype(dt)
        us, gs = jnp.split(hs, 2, axis=-1)
        out = out + (jax.nn.silu(gs) * us) @ p["shared_wo"].astype(dt)
    return out.reshape(b, s_len, d), aux


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(key, cfg):
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope + cfg.qk_rope
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (d, h * qk), ("embed", "heads_dim"), jnp.float32)
    p["wdkv"], s["wdkv"] = dense_init(ks[1], (d, cfg.kv_lora), ("embed", "lora"), jnp.float32)
    p["wkr"], s["wkr"] = dense_init(ks[2], (d, cfg.qk_rope), ("embed", "lora"), jnp.float32)
    p["wuk"], s["wuk"] = dense_init(ks[3], (cfg.kv_lora, h * cfg.qk_nope), ("lora", "heads_dim"), jnp.float32)
    p["wuv"], s["wuv"] = dense_init(ks[4], (cfg.kv_lora, h * cfg.v_head), ("lora", "heads_dim"), jnp.float32)
    p["wo"], s["wo"] = dense_init(ks[5], (h * cfg.v_head, d), ("heads_dim", "embed"), jnp.float32)
    return p, s


def mla_expand_kv(p, ckv, k_rope, cfg, dt):
    """Latent cache -> full K, V. ckv: (B, S, lora); k_rope: (B, S, qk_rope)."""
    b, s_len, _ = ckv.shape
    h = cfg.n_heads
    k_nope = (ckv @ p["wuk"].astype(dt)).reshape(b, s_len, h, cfg.qk_nope)
    v = (ckv @ p["wuv"].astype(dt)).reshape(b, s_len, h, cfg.v_head)
    kr = jnp.broadcast_to(k_rope[:, :, None, :], (b, s_len, h, cfg.qk_rope))
    k = jnp.concatenate([k_nope, kr.astype(dt)], axis=-1)
    return k, v


def mla_qkv(p, x, positions, cfg):
    """Returns (q, ckv, k_rope): q rope-applied; latent parts for the cache."""
    b, s_len, _ = x.shape
    dt = x.dtype
    h = cfg.n_heads
    qk = cfg.qk_nope + cfg.qk_rope
    q = (x @ p["wq"].astype(dt)).reshape(b, s_len, h, qk)
    q_nope, q_rope = q[..., : cfg.qk_nope], q[..., cfg.qk_nope :]
    q_rope = rope(q_rope, positions[None, :], cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    ckv = x @ p["wdkv"].astype(dt)                               # (B, S, lora)
    k_rope = rope(
        (x @ p["wkr"].astype(dt))[:, :, None, :], positions[None, :], cfg.rope_theta
    )[:, :, 0, :]
    return q, ckv, k_rope
