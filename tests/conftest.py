import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); keep any user XLA_FLAGS out of the way.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def blobs():
    rng = np.random.default_rng(42)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(80, 2)),
        rng.normal((4, 0), 0.5, size=(80, 2)),
        rng.normal((2, 4), 0.4, size=(60, 2)),
        rng.uniform(-2, 6, size=(20, 2)),
    ]).astype(np.float32)
    gt = np.repeat([0, 1, 2, 3], [80, 80, 60, 20])
    return x, gt


@pytest.fixture(scope="session")
def gauss16d():
    rng = np.random.default_rng(7)
    centers = rng.uniform(-8, 8, size=(6, 16))
    x = np.concatenate(
        [rng.normal(c, 1.0, size=(120, 16)) for c in centers]
    ).astype(np.float32)
    return x
