"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth that the corresponding kernel is
tested against (tests/test_kernels.py sweeps shapes and dtypes and asserts
allclose / exact index agreement).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_d2_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """(n, d) x (m, d) -> (n, m) squared Euclidean distances, fp32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1)[:, None]
        + jnp.sum(y * y, -1)[None, :]
        - 2.0 * x @ y.T
    )
    return jnp.maximum(d2, 0.0)


def knn_ref(x: jax.Array, k_top: int) -> tuple[jax.Array, jax.Array]:
    """Exact kNN oracle: full matrix + top_k. (d2 ascending, idx), self excluded."""
    n = x.shape[0]
    d2 = pairwise_d2_ref(x, x)
    d2 = d2.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k_top)
    return -neg, idx


def lune_filter_ref(
    a_xyz, b_xyz, a_cd2, b_cd2, a_idx, b_idx, w2, points, cd2
) -> jax.Array:
    """Oracle for lune_filter: (m,) bool, True = some point strictly inside lune.

    Applies the same norm-scaled cancellation margin as the kernel (see
    lune_filter.py): numeric noise may only KEEP edges, never drop them.
    """
    d2_ac = pairwise_d2_ref(a_xyz, points)          # (m, n)
    d2_bc = pairwise_d2_ref(b_xyz, points)
    mrd_ac = jnp.maximum(jnp.maximum(d2_ac, a_cd2[:, None]), cd2[None, :])
    mrd_bc = jnp.maximum(jnp.maximum(d2_bc, b_cd2[:, None]), cd2[None, :])
    eps = jnp.float32(64.0 * 1.1920929e-07)
    an = jnp.sum(a_xyz.astype(jnp.float32) ** 2, -1)[:, None]
    bn = jnp.sum(b_xyz.astype(jnp.float32) ** 2, -1)[:, None]
    cn = jnp.sum(points.astype(jnp.float32) ** 2, -1)[None, :]
    col = jnp.arange(points.shape[0])[None, :]
    is_ep = (col == a_idx[:, None]) | (col == b_idx[:, None])
    inside = (
        jnp.maximum(mrd_ac + eps * (an + cn), mrd_bc + eps * (bn + cn))
        < w2[:, None]
    ) & ~is_ep
    return jnp.any(inside, axis=1)
