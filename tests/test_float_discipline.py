"""Regression guard for the ``is np.inf`` bug class (PR 4) and pinning of
the float comparisons that are INTENTIONALLY exact.

An ``is np.inf`` identity check is False for any *computed* inf (only the
module-level singleton matches), so it silently falls through to the generic
branch — the dbcv misrouting fixed in PR 4.  The lint test here keeps the
whole class out of ``src/``; the other tests pin the two deliberate exact
comparisons the audit found, so a future "fix" doesn't relax them:

  * ``rng.filter_cascade_device``'s core-distance certificate
    ``w2 == max(cd_a, cd_b)``: ``w2`` is literally ``max(d2, cd_a, cd_b)``
    of the same float values, so when a core distance dominates, the bit
    pattern round-trips and exact equality is the *correct* test (an eps
    band would certify near-misses that are not provably RNG edges).
  * Borůvka's ``wc == wmin[component]`` re-read: a float written to an
    array and compared against itself is exact by IEEE-754; the comparison
    selects edges achieving the recorded component minimum.
"""

import pathlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import engine  # noqa: E402
from repro.core import mrd, rng  # noqa: E402

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def test_no_identity_comparison_with_float_singletons():
    """``is np.inf`` / ``is np.nan`` never appears in src/ (the PR-4 bug
    class: identity is False for any computed inf/nan).  AST-based so
    docstrings describing the bug don't trip it."""
    import ast

    def is_float_singleton(node):
        return (
            isinstance(node, ast.Attribute)
            and node.attr in ("inf", "nan")
            and isinstance(node.value, ast.Name)
            and node.value.id in ("np", "numpy", "math", "jnp")
        )

    offenders = []
    for py in sorted(SRC.rglob("*.py")):
        tree = ast.parse(py.read_text())
        for node in ast.walk(tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left, *node.comparators]
            for i, op in enumerate(node.ops):
                if isinstance(op, (ast.Is, ast.IsNot)) and (
                    is_float_singleton(sides[i])
                    or is_float_singleton(sides[i + 1])
                ):
                    offenders.append(f"{py.relative_to(SRC)}:{node.lineno}")
    assert not offenders, "\n".join(offenders)


def test_identity_check_is_false_for_computed_inf():
    """The failure mode itself, pinned: a computed inf is == np.inf but is
    NOT the singleton, so only value/isinf checks may guard inf branches."""
    computed = np.float64("inf")
    assert computed == np.inf and np.isinf(computed)
    assert computed is not np.inf


def test_rng_certificate_exact_equality_is_sound():
    """The core-distance certificate fires exactly when a core distance
    dominates the edge (w2 == max(cd) bit-for-bit), and never when the
    pairwise distance strictly dominates."""
    # 1-D layout: a dense clump [0, .1, .2, .3] plus a far point at 100.
    # With k=2 core distances, clump<->far edges are cd-dominated on the
    # far point's side; intra-clump edges are d2- or cd-dominated per pair.
    x = jnp.asarray([[0.0], [0.1], [0.2], [0.3], [100.0]], jnp.float32)
    n = 5
    d2 = np.asarray((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    knn_d2 = np.sort(d2, axis=1)[:, :2].astype(np.float32)
    knn_idx = np.argsort(d2, axis=1)[:, :2].astype(np.int32)
    cd2k = knn_d2[:, -1]

    lo, hi = np.triu_indices(n, 1)
    plan = engine.resolve_plan("auto")
    keep, certified, inside_any, d2_e, w2 = rng.filter_cascade_device(
        x,
        jnp.asarray(knn_d2),
        jnp.asarray(knn_idx),
        jnp.asarray(knn_d2),
        jnp.asarray(lo, jnp.int32),
        jnp.asarray(hi, jnp.int32),
        jnp.ones(len(lo), bool),
        plan=plan,
    )
    certified = np.asarray(certified)
    w2 = np.asarray(w2)
    expect = np.maximum(d2_e, np.maximum(cd2k[lo], cd2k[hi]))
    np.testing.assert_array_equal(w2, np.asarray(expect, np.float32))
    # certificate == "a core distance attains the max", bitwise
    dominated = w2 == np.maximum(cd2k[lo], cd2k[hi])
    np.testing.assert_array_equal(certified, dominated)
    assert dominated.any() and not dominated.all()


def test_mrd_max_roundtrips_core_distance_bits():
    """mrd2_from_parts returns the dominating core distance's exact bit
    pattern (jnp.maximum selects, never recomputes) — the property the
    certificate's exact equality relies on."""
    d2 = jnp.asarray([1.0, 2.5], jnp.float32)
    ca = jnp.asarray([3.7000003, 0.5], jnp.float32)  # odd mantissas
    cb = jnp.asarray([0.25, 1.1920929e-7], jnp.float32)
    w2 = np.asarray(mrd.mrd2_from_parts(d2, ca, cb))
    assert w2[0] == np.float32(3.7000003)
    assert w2[1] == np.float32(2.5)
