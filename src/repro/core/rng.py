"""RNG construction pipeline: RNG** -> RNG* -> exact RNG (paper §IV-E, Alg. 1).

Variants (paper's naming):
  * ``rng_ss``  (RNG**): WSPD+SBCN supergraph, no filtering (Alg. 1 line 12).
  * ``rng_star`` (RNG*): + the 2*kmax-check filter using each endpoint's
    kmax-NN list, plus the core-distance certificate for definite keeps
    (lines 13-21).  May keep some non-RNG edges.
  * ``rng``     (exact): + full-dataset lune scan for edges the cheap filter
    could not certify either way (lines 22-26) — the Pallas ``lune_filter``
    kernel / its jnp twin.

All predicates run in squared space (see core.mrd).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .. import kernels
from . import mrd as mrd_mod
from . import sbcn as sbcn_mod
from . import wspd as wspd_mod

VARIANTS = ("rng_ss", "rng_star", "rng")


@dataclasses.dataclass
class RngGraph:
    """The single precomputed graph that serves the whole mpts range."""

    edges: np.ndarray      # (m, 2) int64, a < b
    d2: np.ndarray         # (m,)  squared Euclidean edge lengths
    w2_kmax: np.ndarray    # (m,)  squared mrd_kmax weights
    variant: str
    n_points: int
    stats: dict


@functools.partial(jax.jit, static_argnames=("chunk",))
def _knn_lune_check(x, cd2k, knn_idx, knn_d2, ea, eb, w2, *, chunk: int = 16384):
    """Paper lines 14-17: is any kmax-NN of a or b strictly inside lune(a,b)?

    Tie robustness: mrd ties are STRUCTURAL here (e.g. c is b's kmax-th
    neighbor => mrd(b,c) = cd(b) = mrd(a,b) exactly in real arithmetic), and
    f32 noise — including XLA's per-callsite FMA contraction, which makes
    even identical formulas differ by ulps across call sites — must never
    flip a tie into a removal.  Two defenses: (1) own-list distances are read
    from the stored kNN pass instead of recomputed, making the most common
    tie bit-exact; (2) a norm-scaled epsilon margin is added on the "inside"
    side, so residual noise can only KEEP an edge (the superset-safe
    direction), mirroring the exact-filter kernel.

    Returns (m,) bool `inside_any`.
    """
    eps = jnp.float32(64.0 * 1.1920929e-07)

    def one_chunk(args):
        ea_c, eb_c, w2_c = args
        cand_a = knn_idx[ea_c]                                           # (c, k)
        cand_b = knn_idx[eb_c]
        xa = x[ea_c].astype(jnp.float32)
        xb = x[eb_c].astype(jnp.float32)
        xca = x[cand_a].astype(jnp.float32)                              # (c, k, d)
        xcb = x[cand_b].astype(jnp.float32)
        # own-list distances come from storage; cross distances are recomputed
        d2a_ca = knn_d2[ea_c]                                            # d2(a, cand_a)
        d2b_cb = knn_d2[eb_c]                                            # d2(b, cand_b)
        d2b_ca = jnp.sum((xb[:, None, :] - xca) ** 2, -1)                # d2(b, cand_a)
        d2a_cb = jnp.sum((xa[:, None, :] - xcb) ** 2, -1)                # d2(a, cand_b)

        cda = cd2k[ea_c][:, None]
        cdb = cd2k[eb_c][:, None]
        an = jnp.sum(xa * xa, -1)[:, None]
        bn = jnp.sum(xb * xb, -1)[:, None]

        def inside(cand, xc, d2ac, d2bc):
            cdc = cd2k[cand]
            cn = jnp.sum(xc * xc, -1)
            mrd_ac = jnp.maximum(jnp.maximum(d2ac, cda), cdc) + eps * (an + cn)
            mrd_bc = jnp.maximum(jnp.maximum(d2bc, cdb), cdc) + eps * (bn + cn)
            not_ep = (cand != ea_c[:, None]) & (cand != eb_c[:, None])
            return jnp.any(
                (jnp.maximum(mrd_ac, mrd_bc) < w2_c[:, None]) & not_ep, axis=1
            )

        return inside(cand_a, xca, d2a_ca, d2b_ca) | inside(cand_b, xcb, d2a_cb, d2b_cb)

    m = ea.shape[0]
    m_pad = -(-m // chunk) * chunk
    pad = lambda v, f: jnp.concatenate(  # noqa: E731
        [v, jnp.full((m_pad - m,), f, v.dtype)]
    )
    ea_p, eb_p = pad(ea, 0), pad(eb, 0)
    w2_p = pad(w2, -jnp.inf)  # padded edges can never have points inside
    res = jax.lax.map(
        one_chunk,
        (
            ea_p.reshape(-1, chunk),
            eb_p.reshape(-1, chunk),
            w2_p.reshape(-1, chunk),
        ),
    )
    return res.reshape(m_pad)[:m]


def filter_edges(
    x: jax.Array,
    cd2: jax.Array,
    knn_idx: jax.Array,
    knn_d2: jax.Array,
    edges: np.ndarray,
    variant: str,
    *,
    backend: str | None = None,
) -> tuple[np.ndarray, dict]:
    """Apply the paper's filter cascade to candidate `edges`.

    Returns (kept edge array, stats dict).
    """
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}")
    stats = {"m_candidates": int(len(edges))}
    if variant == "rng_ss" or len(edges) == 0:
        return edges, stats

    cd2k = cd2[:, -1]
    ea = jnp.asarray(edges[:, 0], jnp.int32)
    eb = jnp.asarray(edges[:, 1], jnp.int32)
    d2_e = mrd_mod.edge_d2(x, ea, eb)
    w2 = mrd_mod.mrd2_from_parts(d2_e, cd2k[ea], cd2k[eb])

    inside_any = np.asarray(_knn_lune_check(x, cd2k, knn_idx, knn_d2, ea, eb, w2))
    # core-distance certificate: w == max(c(a), c(b))  =>  definitely in RNG
    certified = np.asarray(w2 == jnp.maximum(cd2k[ea], cd2k[eb]))

    keep = ~inside_any
    stats["m_removed_knn"] = int(inside_any.sum())
    stats["m_certified"] = int((keep & certified).sum())

    if variant == "rng":
        unresolved = keep & ~certified
        stats["m_unresolved"] = int(unresolved.sum())
        if unresolved.any():
            ui = np.nonzero(unresolved)[0]
            nonempty = np.asarray(
                kernels.ops.lune_nonempty(
                    ea[ui], eb[ui], w2[ui], x, cd2k, backend=backend
                )
            )
            keep[ui[nonempty]] = False
            stats["m_removed_exact"] = int(nonempty.sum())
    return edges[keep], stats


def build_rng_graph(
    x: jax.Array,
    knn_d2: jax.Array,
    knn_idx: jax.Array,
    *,
    variant: str = "rng_star",
    separation: float = 1.0,
    backend: str | None = None,
) -> RngGraph:
    """End-to-end RNG^kmax construction (Alg. 1 lines 5-29).

    knn_d2/knn_idx: the single (kmax-1)-NN pass (ascending squared distances).
    """
    n = x.shape[0]
    cd2 = mrd_mod.core_distances2(knn_d2)
    cd_kmax = np.sqrt(np.asarray(cd2[:, -1], np.float64))

    tree = wspd_mod.build_fair_split_tree(np.asarray(x, np.float64), cd_kmax)
    pu, pv = wspd_mod.wspd_pairs(tree, s=separation)
    candidates = sbcn_mod.sbcn_edges(
        x,
        cd2[:, -1],
        tree.perm,
        tree.start[pu],
        tree.end[pu] - tree.start[pu],
        tree.start[pv],
        tree.end[pv] - tree.start[pv],
    )

    edges, stats = filter_edges(
        x, cd2, knn_idx, knn_d2, candidates, variant, backend=backend
    )
    stats["n_wspd_pairs"] = int(len(pu))
    stats["m_edges"] = int(len(edges))

    ea = jnp.asarray(edges[:, 0], jnp.int32)
    eb = jnp.asarray(edges[:, 1], jnp.int32)
    d2_e = np.asarray(mrd_mod.edge_d2(x, ea, eb))
    w2 = np.maximum(np.maximum(np.asarray(cd2[:, -1])[edges[:, 0]],
                               np.asarray(cd2[:, -1])[edges[:, 1]]), d2_e)
    return RngGraph(
        edges=edges, d2=d2_e, w2_kmax=w2, variant=variant, n_points=n, stats=stats
    )
