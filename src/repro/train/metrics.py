"""Metrics logging + straggler detection hooks.

``StepTimer`` keeps an EMA of step wall-time and flags outliers (straggler
mitigation at the host level: in a multi-host deployment the flagged host
reports itself to the coordinator, which can evict/replace it — here the
detection logic and the log trail are what we can realize and test).
"""

from __future__ import annotations

import json
import time


class JsonlLogger:
    def __init__(self, path: str | None):
        self.path = path
        self._f = open(path, "a") if path else None

    def log(self, step: int, **kv):
        rec = {"step": step, "t": time.time(), **{k: _tofloat(v) for k, v in kv.items()}}
        line = json.dumps(rec)
        if self._f:
            self._f.write(line + "\n")
            self._f.flush()
        return line

    def close(self):
        if self._f:
            self._f.close()


def _tofloat(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class StepTimer:
    """EMA step timer with straggler flagging (z-like threshold on EMA)."""

    def __init__(self, alpha: float = 0.1, slow_factor: float = 2.5):
        self.alpha = alpha
        self.slow_factor = slow_factor
        self.ema = None
        self.last = None
        self.stragglers = 0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.observe(time.monotonic() - self._t0)

    def observe(self, dt: float):
        self.last = dt
        self._flagged = False
        if self.ema is None:
            self.ema = dt
        else:
            if dt > self.slow_factor * self.ema:
                self.stragglers += 1
                self._flagged = True
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt

    @property
    def is_straggler(self) -> bool:
        """Was the most recent step flagged (vs the EMA at observe time)?"""
        return getattr(self, "_flagged", False)
