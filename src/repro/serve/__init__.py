from . import engine

__all__ = ["engine"]
