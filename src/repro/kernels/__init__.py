"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp twins).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
dispatching wrappers), ref.py (pure-jnp oracles used by tests).
"""

from . import fused_cascade, ops, ref
from .fused_cascade import edge_cascade
from .lune_filter import lune_filter
from .pairwise_topk import pairwise_topk

__all__ = [
    "edge_cascade", "fused_cascade", "lune_filter", "ops", "pairwise_topk",
    "ref",
]
