"""Data pipeline: deterministic synthetic corpus + packing + resume state.

The generator is a pure function of (seed, step), so checkpoint-resume
reproduces the exact same batch stream with no iterator state to persist
beyond the step counter — the simplest correct form of data-pipeline fault
tolerance (and what the resume test asserts).

Synthetic text is a Zipf-ish Markov stream (not uniform noise) so language-
model training losses actually descend, and document boundaries + packing
emulate a production mixture.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 50_000
    seq_len: int = 1024
    global_batch: int = 8
    mean_doc_len: int = 384
    zipf_a: float = 1.3


def _batch_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """(B, S+1) int32, deterministic in (seed, step). Zipf unigram + doc breaks."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s = cfg.global_batch, cfg.seq_len + 1
    # zipf over vocab, clipped
    toks = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
    toks = (toks - 1) % (cfg.vocab - 2) + 2          # reserve 0=BOS, 1=EOS
    # inject document boundaries (packing): geometric doc lengths
    n_docs = max(1, s // cfg.mean_doc_len)
    for i in range(b):
        cuts = rng.integers(1, s - 1, size=n_docs)
        toks[i, cuts] = 1
        toks[i, np.minimum(cuts + 1, s - 1)] = 0
    toks[:, 0] = 0
    return toks.astype(np.int32)


def train_batch(cfg: DataConfig, step: int) -> dict:
    """{'tokens': (B, S), 'labels': (B, S), 'mask': (B, S)}."""
    t = _batch_tokens(cfg, step)
    tokens, labels = t[:, :-1], t[:, 1:]
    mask = (labels != 0).astype(np.float32)          # don't predict BOS
    return {
        "tokens": jnp.asarray(tokens),
        "labels": jnp.asarray(labels),
        "mask": jnp.asarray(mask),
    }


def embedding_stream(seed: int, n: int, dim: int, n_modes: int = 12) -> np.ndarray:
    """Synthetic 'document embedding' stream with cluster structure, for the
    clustering-engine examples (stands in for LM-pooled embeddings)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 4.0, size=(n_modes, dim))
    scales = rng.uniform(0.3, 1.2, size=n_modes)
    which = rng.integers(0, n_modes, size=n)
    x = centers[which] + rng.normal(size=(n, dim)) * scales[which][:, None]
    # 5% uniform background noise
    noise = rng.random(n) < 0.05
    x[noise] = rng.uniform(-8, 8, size=(int(noise.sum()), dim))
    return x.astype(np.float32)
