"""llava-next-34b [vlm] — 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Transformer backbone only (Yi-34B-class); the anyres-tiling vision frontend
is a STUB per the task: input_specs() feeds precomputed patch embeddings
(B, n_patch, 1152) through a 2-layer MLP projector into the token stream.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    arch="transformer",
    vocab=64000,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_head=128,
    n_layers=60,
    d_ff=20480,
    act="swiglu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    frontend="patches",
    frontend_dim=1152,
    frontend_tokens_4k=2880,        # anyres 2880 patch positions + 1216 text
    microbatch=4,
    run_long_500k=False,
    skip_note="pure full attention; long_500k skipped per task rule",
)
