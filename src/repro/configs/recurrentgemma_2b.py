"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.

Griffin block pattern (RG-LRU, RG-LRU, local-attn w=2048) ~ 1:2 attn:recurrent,
head_dim 256, GeGLU.  [arXiv:2402.19427; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    arch="griffin",
    vocab=256000,
    d_model=2560,
    n_layers=26,                    # (R,R,A) x 8 + (R,R)
    n_heads=10,
    n_kv=1,
    d_head=256,
    d_ff=7680,
    act="geglu",
    window=2048,
    block_pattern=("R", "R", "A"),
    run_long_500k=True,             # bounded state: LRU + 2048 window
)
