"""HDBSCAN* hierarchy extraction: dendrogram -> condensed tree -> clusters.

Host-side post-processing (numpy): consumes the (n-1)-edge MST produced on
device and is O(n alpha(n)) scalar work (DESIGN.md §3).  Implements the
standard HDBSCAN* machinery (Campello et al. 2013/2015):

  * ``single_linkage``  — scipy-style merge matrix Z via union-find over
    weight-sorted MST edges.
  * ``condense_tree``   — collapse the dendrogram w.r.t. ``min_cluster_size``:
    a node is a *true split* iff both children have >= mcs points; otherwise
    points "fall out" of the surviving cluster at that lambda = 1/distance.
  * ``compute_stability`` / ``extract_clusters`` — cluster selection from the
    condensed tree: excess-of-mass (FOSC, bottom-up) or condensed-tree leaves.
  * ``labels_for``      — final labels (-1 = noise) + per-point lambdas.

Two implementations coexist:

  * The *reference* path (``single_linkage`` + ``condense_tree`` +
    ``labels_for``) is the per-edge / per-row Python-loop transliteration of
    Campello et al.; it is the oracle that tests compare against.
  * The *vectorized* path (``condense_tree_fast`` + ``compute_stability_fast``
    + ``labels_for_fast``, composed by ``extract_condensed``) is pure
    numpy array work — pointer-doubling over the dendrogram instead of
    top-down recursion — and is what the production pipeline
    (``core.multi`` / ``repro.api``) runs, downstream of the batched device
    linkage in ``core.linkage``.  ``tests/test_hierarchy.py`` pins the two
    paths against each other.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def single_linkage(ea: np.ndarray, eb: np.ndarray, w: np.ndarray, n: int) -> np.ndarray:
    """Union-find single linkage. Returns Z (n-1, 4): left, right, dist, size.

    Cluster ids: 0..n-1 are points; n+i is the cluster formed by row i.
    Edges must form a spanning tree; `w` are (non-squared) distances.
    """
    order = np.lexsort((np.arange(len(w)), w))
    parent = np.arange(2 * n - 1, dtype=np.int64)
    uf_label = np.arange(n, dtype=np.int64)  # current cluster label of each root
    size = np.ones(2 * n - 1, dtype=np.int64)

    def find(v):
        root = v
        while parent[root] != root:
            root = parent[root]
        while parent[v] != root:  # path compression
            parent[v], v = root, parent[v]
        return root

    Z = np.zeros((n - 1, 4), np.float64)
    nxt = 0
    for ei in order:
        ra, rb = find(ea[ei]), find(eb[ei])
        if ra == rb:
            continue
        la, lb = uf_label[ra], uf_label[rb]
        new = n + nxt
        merged = size[la] + size[lb]
        Z[nxt] = (la, lb, w[ei], merged)
        size[new] = merged
        # merge union-find roots
        parent[ra] = rb
        uf_label[rb] = new
        nxt += 1
    if nxt != n - 1:
        raise ValueError(f"edge list does not span: {nxt + 1} components remain")
    return Z


@dataclasses.dataclass
class CondensedTree:
    parent: np.ndarray      # (k,) condensed parent cluster id (>= n)
    child: np.ndarray       # (k,) point id (< n) or child cluster id (>= n)
    lam: np.ndarray         # (k,) lambda = 1/dist at which child leaves parent
    child_size: np.ndarray  # (k,)
    n_points: int
    root: int               # root cluster id (== n_points)


def condense_tree(Z: np.ndarray, n: int, min_cluster_size: int) -> CondensedTree:
    """Condense a single-linkage dendrogram (hdbscan-style, iterative BFS)."""
    root = 2 * n - 2  # top merge (dendrogram id n + (n-2))
    next_label = n + 1
    relabel = {root: n}

    parents: list[int] = []
    children: list[int] = []
    lams: list[float] = []
    sizes: list[int] = []

    def node_info(node):
        """(left, right, dist, size) for dendrogram node id; points -> leaf."""
        row = Z[node - n]
        return int(row[0]), int(row[1]), float(row[2]), int(row[3])

    def node_size(node):
        return 1 if node < n else int(Z[node - n][3])

    def leaves_of(node):
        out = []
        stack = [node]
        while stack:
            v = stack.pop()
            if v < n:
                out.append(v)
            else:
                l, r, _, _ = node_info(v)
                stack.extend((l, r))
        return out

    ignore = set()
    # BFS top-down over dendrogram nodes that still carry a cluster label.
    stack = [root]
    while stack:
        node = stack.pop()
        if node in ignore or node < n:
            continue
        cur_label = relabel[node]
        left, right, dist, _ = node_info(node)
        lam = 1.0 / dist if dist > 0.0 else np.inf
        ls, rs = node_size(left), node_size(right)

        if ls >= min_cluster_size and rs >= min_cluster_size:
            for ch, s in ((left, ls), (right, rs)):
                relabel[ch] = next_label
                parents.append(cur_label)
                children.append(next_label)
                lams.append(lam)
                sizes.append(s)
                next_label += 1
                stack.append(ch)
        else:
            for ch, s in ((left, ls), (right, rs)):
                if s >= min_cluster_size:
                    relabel[ch] = cur_label  # cluster continues under same label
                    stack.append(ch)
                else:
                    for p in leaves_of(ch):  # points fall out at this lambda
                        parents.append(cur_label)
                        children.append(p)
                        lams.append(lam)
                        sizes.append(1)
                    ignore.add(ch)

    return CondensedTree(
        parent=np.asarray(parents, np.int64),
        child=np.asarray(children, np.int64),
        lam=np.asarray(lams, np.float64),
        child_size=np.asarray(sizes, np.int64),
        n_points=n,
        root=n,
    )


def _pointer_double(ptr: np.ndarray, done: np.ndarray) -> np.ndarray:
    """Jump each pointer to its nearest ancestor with ``done[anc]`` True.

    ``ptr`` maps node -> an ancestor-or-self; entries with ``done[ptr]`` are
    fixed points.  O(log chain-length) rounds, each a vectorized gather.
    """
    for _ in range(70):  # 2^70 >> any chain length representable here
        nxt = np.where(done[ptr], ptr, ptr[ptr])
        if np.array_equal(nxt, ptr):
            return ptr
        ptr = nxt
    raise RuntimeError("pointer doubling failed to converge")


def condense_tree_fast(Z: np.ndarray, n: int, min_cluster_size: int) -> CondensedTree:
    """Vectorized ``condense_tree``: no per-node Python recursion.

    Same semantics as the reference (row order and condensed-label numbering
    may differ; both are free choices that no consumer depends on — labels
    are assigned top-down so every parent id < child id, the invariant
    ``extract_clusters`` relies on).
    """
    if min_cluster_size < 2:
        raise ValueError("condense_tree_fast requires min_cluster_size >= 2")
    n_merges = n - 1
    left = Z[:, 0].astype(np.int64)
    right = Z[:, 1].astype(np.int64)
    dist = Z[:, 2].astype(np.float64)
    n_nodes = 2 * n - 1
    root = 2 * n - 2
    merge_ids = n + np.arange(n_merges, dtype=np.int64)

    size = np.concatenate([np.ones(n, np.int64), Z[:, 3].astype(np.int64)])
    parent = np.arange(n_nodes, dtype=np.int64)  # root stays self-parented
    parent[left] = merge_ids
    parent[right] = merge_ids

    lam_m = np.full(n_merges, np.inf)
    nz = dist > 0.0
    lam_m[nz] = 1.0 / dist[nz]
    lam_node = np.concatenate([np.zeros(n), lam_m])

    # "big" nodes (>= mcs points) form a connected top subtree: sizes strictly
    # increase towards the root.  The root always carries label n even when
    # n < mcs (then every point just falls out of it).
    big = size >= min_cluster_size
    big[root] = True

    # A(p): each point's lowest big ancestor — where it falls out of the tree.
    self_ids = np.arange(n_nodes, dtype=np.int64)
    big_anc = _pointer_double(np.where(big, self_ids, parent), big)

    # True splits: both children keep >= mcs points.  Their two children are
    # the "cluster roots" — nodes where a fresh condensed label is born.
    split = big[left] & big[right]
    is_croot = np.zeros(n_nodes, bool)
    is_croot[left[split]] = True
    is_croot[right[split]] = True
    is_croot[root] = True
    croot_of = _pointer_double(np.where(is_croot, self_ids, parent), is_croot)

    # Fresh ids top-down (ancestors have strictly larger dendrogram node ids,
    # so descending node id is a topological order): root -> n, then n+1, ...
    roots_desc = np.flatnonzero(is_croot)[::-1]
    croot_label = np.full(n_nodes, -1, np.int64)
    croot_label[roots_desc] = n + np.arange(len(roots_desc))

    split_nodes = merge_ids[split]
    lc, rc = left[split], right[split]
    cl_parent = np.repeat(croot_label[croot_of[split_nodes]], 2)
    cl_child = np.stack([croot_label[lc], croot_label[rc]], axis=1).ravel()
    cl_lam = np.repeat(lam_node[split_nodes], 2)
    cl_size = np.stack([size[lc], size[rc]], axis=1).ravel()

    pts = np.arange(n, dtype=np.int64)
    fall = big_anc[pts]
    pt_parent = croot_label[croot_of[fall]]

    return CondensedTree(
        parent=np.concatenate([cl_parent, pt_parent]),
        child=np.concatenate([cl_child, pts]),
        lam=np.concatenate([cl_lam, lam_node[fall]]),
        child_size=np.concatenate([cl_size, np.ones(n, np.int64)]),
        n_points=n,
        root=n,
    )


def compute_stability(tree: CondensedTree) -> dict[int, float]:
    """Excess-of-mass stability: sum_p (lambda_p - lambda_birth(C))."""
    lam_birth: dict[int, float] = {tree.root: 0.0}
    cluster_rows = tree.child >= tree.n_points
    for p, c, l in zip(
        tree.parent[cluster_rows], tree.child[cluster_rows], tree.lam[cluster_rows]
    ):
        lam_birth[int(c)] = float(l)

    stability: dict[int, float] = {c: 0.0 for c in lam_birth}
    finite_cap = np.max(tree.lam[np.isfinite(tree.lam)], initial=1.0)
    for p, l, s in zip(tree.parent, tree.lam, tree.child_size):
        lv = float(l) if np.isfinite(l) else float(finite_cap)
        stability[int(p)] = stability.get(int(p), 0.0) + (lv - lam_birth[int(p)]) * int(s)
    return stability


def compute_stability_fast(tree: CondensedTree) -> dict[int, float]:
    """Vectorized ``compute_stability`` (identical values, no per-row loop)."""
    cluster_rows = tree.child >= tree.n_points
    cids = np.concatenate([[tree.root], tree.child[cluster_rows]]).astype(np.int64)
    births = np.concatenate([[0.0], tree.lam[cluster_rows]])
    sidx = np.argsort(cids)
    scids, sbirths = cids[sidx], births[sidx]

    finite = np.isfinite(tree.lam)
    cap = float(np.max(tree.lam[finite], initial=1.0))
    lam_eff = np.where(finite, tree.lam, cap)

    pos = np.searchsorted(scids, tree.parent)
    totals = np.zeros(len(scids))
    np.add.at(totals, pos, (lam_eff - sbirths[pos]) * tree.child_size)
    return {int(c): float(t) for c, t in zip(scids, totals)}


def _extract_leaves(tree: CondensedTree, allow_single_cluster: bool) -> list[int]:
    """Leaf selection: every condensed cluster with no child clusters."""
    cluster_rows = tree.child >= tree.n_points
    parents = set(int(p) for p in tree.parent[cluster_rows])
    clusters = {tree.root} | set(int(c) for c in tree.child[cluster_rows])
    leaves = sorted(
        c for c in clusters
        if c not in parents and (c != tree.root or allow_single_cluster)
    )
    if not leaves and allow_single_cluster:
        return [tree.root]
    return leaves


def _epsilon_merge(
    tree: CondensedTree,
    selected: list[int],
    epsilon: float,
    allow_single_cluster: bool,
) -> list[int]:
    """Malzer & Baum's epsilon threshold over an already-selected set.

    A selected cluster born at distance < epsilon (birth lambda >
    1/epsilon) is merged upward into its first ancestor born at a distance
    >= epsilon; clusters already epsilon-stable pass through.  Climbing
    stops below the root unless ``allow_single_cluster`` (then the root
    itself can absorb everything) — the hdbscan ``traverse_upwards``
    convention.  Descendants of a kept ancestor are dropped, so the result
    is again an antichain of the condensed tree.
    """
    if epsilon <= 0.0 or not selected:
        return selected
    cluster_rows = tree.child >= tree.n_points
    parent_of = {
        int(c): int(p)
        for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows])
    }
    birth = {
        int(c): float(l)
        for c, l in zip(tree.child[cluster_rows], tree.lam[cluster_rows])
    }

    def eps_of(c: int) -> float:
        lam = birth.get(c, 0.0)  # the root is born at lambda 0 -> eps inf
        return np.inf if lam <= 0.0 else 1.0 / lam

    kept: set[int] = set()
    for c in selected:
        if eps_of(c) >= epsilon:
            kept.add(c)
            continue
        cur = c
        while True:
            par = parent_of.get(cur)
            if par is None:  # cur IS the root (only selectable w/ single ok)
                kept.add(cur)
                break
            if par == tree.root and not allow_single_cluster:
                kept.add(cur)  # closest-to-root node below the forbidden root
                break
            if eps_of(par) >= epsilon:
                kept.add(par)
                break
            cur = par
    # drop any kept cluster that has a kept strict ancestor
    out = []
    for c in sorted(kept):
        anc = parent_of.get(c)
        while anc is not None and anc not in kept:
            anc = parent_of.get(anc)
        if anc is None:
            out.append(c)
    return out


def extract_clusters(
    tree: CondensedTree,
    stability: dict[int, float],
    *,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
    cluster_selection_epsilon: float = 0.0,
) -> list[int]:
    """Cluster selection; returns selected condensed cluster ids.

    ``"eom"`` is FOSC bottom-up excess-of-mass (the HDBSCAN* default);
    ``"leaf"`` takes the leaves of the condensed tree — many small
    fine-grained clusters.  A positive ``cluster_selection_epsilon`` then
    applies Malzer & Baum's hybrid threshold on top of either method:
    selected clusters born at a distance below epsilon are merged upward
    into their first epsilon-stable ancestor (see ``_epsilon_merge``).
    """
    if cluster_selection_method == "leaf":
        return _epsilon_merge(
            tree,
            _extract_leaves(tree, allow_single_cluster),
            cluster_selection_epsilon,
            allow_single_cluster,
        )
    if cluster_selection_method != "eom":
        raise ValueError(
            f"cluster_selection_method must be 'eom' or 'leaf'; "
            f"got {cluster_selection_method!r}"
        )
    children_of: dict[int, list[int]] = {}
    cluster_rows = tree.child >= tree.n_points
    for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows]):
        children_of.setdefault(int(p), []).append(int(c))

    clusters = sorted(stability.keys(), reverse=True)  # children have larger ids
    selected = {c: True for c in clusters}
    subtree_val = dict(stability)
    for c in clusters:
        kids = children_of.get(c, [])
        if not kids:
            continue
        kid_sum = sum(subtree_val[k] for k in kids)
        if kid_sum > stability[c] or (c == tree.root and not allow_single_cluster):
            selected[c] = False
            subtree_val[c] = kid_sum
        else:
            # select c; deselect entire subtree below
            stack = list(kids)
            while stack:
                k = stack.pop()
                selected[k] = False
                stack.extend(children_of.get(k, []))
    if not allow_single_cluster:
        selected[tree.root] = False
    return _epsilon_merge(
        tree,
        [c for c in clusters if selected[c]],
        cluster_selection_epsilon,
        allow_single_cluster,
    )


def labels_for(tree: CondensedTree, selected: list[int]) -> tuple[np.ndarray, np.ndarray]:
    """Per-point labels (-1 noise) and the lambda at which each point departs."""
    n = tree.n_points
    labels = np.full(n, -1, np.int64)
    lam_pt = np.zeros(n, np.float64)

    sel = set(selected)
    # map each condensed cluster to its selected ancestor (or -1)
    parent_of: dict[int, int] = {}
    cluster_rows = tree.child >= n
    for p, c in zip(tree.parent[cluster_rows], tree.child[cluster_rows]):
        parent_of[int(c)] = int(p)

    def selected_ancestor(c: int) -> int:
        while True:
            if c in sel:
                return c
            if c not in parent_of:
                return -1
            c = parent_of[c]

    cache: dict[int, int] = {}
    point_rows = ~cluster_rows
    label_ids = {c: i for i, c in enumerate(sorted(sel))}
    for p, c, l in zip(
        tree.parent[point_rows], tree.child[point_rows], tree.lam[point_rows]
    ):
        p = int(p)
        if p not in cache:
            cache[p] = selected_ancestor(p)
        anc = cache[p]
        if anc != -1:
            labels[int(c)] = label_ids[anc]
            lam_pt[int(c)] = l
    return labels, lam_pt


def labels_for_fast(
    tree: CondensedTree, selected: list[int]
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized ``labels_for``: same labels, no per-point Python loop."""
    n = tree.n_points
    labels = np.full(n, -1, np.int64)
    lam_pt = np.zeros(n, np.float64)

    cluster_rows = tree.child >= n
    cids = np.concatenate([[tree.root], tree.child[cluster_rows]]).astype(np.int64)
    cpar = np.concatenate([[-1], tree.parent[cluster_rows]]).astype(np.int64)
    n_c = len(cids)
    sidx = np.argsort(cids)
    scids = cids[sidx]

    def to_idx(ids):
        return sidx[np.searchsorted(scids, ids)]

    # compact parent pointers, with index n_c as an absorbing "no ancestor"
    par_idx = np.full(n_c + 1, n_c, np.int64)
    has_par = cpar >= 0
    par_idx[:n_c][has_par] = to_idx(cpar[has_par])

    sel_mask = np.zeros(n_c + 1, bool)
    if selected:
        sel_mask[to_idx(np.asarray(selected, np.int64))] = True

    done = sel_mask.copy()
    done[n_c] = True  # the sentinel is a fixed point
    ptr = _pointer_double(
        np.where(done, np.arange(n_c + 1, dtype=np.int64), par_idx), done
    )

    # label numbering matches the reference: sorted selected ids -> 0..k-1
    anc_label = np.full(n_c + 1, -1, np.int64)
    for rank, c in enumerate(sorted(selected)):
        anc_label[to_idx(np.int64(c))] = rank

    point_rows = ~cluster_rows
    lab = anc_label[ptr[to_idx(tree.parent[point_rows])]]
    children = tree.child[point_rows]
    labels[children] = lab
    lam_pt[children] = np.where(lab >= 0, tree.lam[point_rows], 0.0)
    return labels, lam_pt


def extract_condensed(
    Z: np.ndarray,
    n: int,
    min_cluster_size: int,
    *,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
) -> tuple[np.ndarray, CondensedTree, dict[int, float]]:
    """Vectorized merge-matrix -> (labels, condensed tree, stability)."""
    tree = condense_tree_fast(Z, n, min_cluster_size)
    stability = compute_stability_fast(tree)
    selected = extract_clusters(
        tree,
        stability,
        allow_single_cluster=allow_single_cluster,
        cluster_selection_method=cluster_selection_method,
    )
    labels, _ = labels_for_fast(tree, selected)
    return labels, tree, stability


def hdbscan_labels(
    ea: np.ndarray,
    eb: np.ndarray,
    w: np.ndarray,
    n: int,
    min_cluster_size: int,
    *,
    allow_single_cluster: bool = False,
    cluster_selection_method: str = "eom",
) -> tuple[np.ndarray, CondensedTree, dict[int, float]]:
    """MST edges -> (labels, condensed tree, stability). `w` = real distances.

    This is the *reference* (per-edge Python loop) path, kept as the oracle;
    the production pipeline runs ``core.linkage.single_linkage_batch`` +
    ``extract_condensed`` instead.
    """
    Z = single_linkage(ea, eb, w, n)
    tree = condense_tree(Z, n, min_cluster_size)
    stability = compute_stability(tree)
    selected = extract_clusters(
        tree,
        stability,
        allow_single_cluster=allow_single_cluster,
        cluster_selection_method=cluster_selection_method,
    )
    labels, _ = labels_for(tree, selected)
    return labels, tree, stability
