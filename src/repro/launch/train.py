"""Training launcher: real steps on the host mesh, full fault-tolerance loop.

Features exercised end-to-end (and by tests/test_train_loop.py):
  * --arch <id> reduced or full configs, synthetic deterministic data
  * checkpoint/auto-resume (atomic commit, async save)
  * --preempt-after N: SIGTERM-style mid-run abort drill; a relaunch resumes
    bit-exact from the last checkpoint (data pipeline is (seed, step)-pure)
  * straggler detection log (metrics.StepTimer)

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b --reduced \
      --steps 30 --ckpt-dir /tmp/ckpt --ckpt-every 10
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist import sharding as shardlib
from repro.launch.mesh import make_host_mesh
from repro.models import get_model, init_params
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import metrics as metrics_lib
from repro.train import optim as optim_mod
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None)
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="simulate preemption: hard-exit after N steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, microbatch=1)
    mesh = make_host_mesh()
    rules = shardlib.resolve_rules(mesh)

    opt_cfg = optim_mod.OptConfig(
        lr=args.lr, warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps, state_dtype=cfg.optimizer_state_dtype,
    )
    opt_init, _ = optim_mod.make_optimizer(opt_cfg)
    raw_step = make_train_step(cfg, opt_cfg)

    def step_fn(params, opt_state, batch):
        with shardlib.activation_context(mesh, rules):
            return raw_step(params, opt_state, batch)

    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    dcfg = data_lib.DataConfig(
        seed=args.seed, vocab=cfg.vocab, seq_len=args.seq_len,
        global_batch=args.global_batch,
    )

    start_step = 0
    if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt_lib.restore(args.ckpt_dir)
        params, opt_state = state["params"], state["opt"]
        params = jax.tree.map(
            lambda x: jnp.asarray(x), params
        )
        opt_state = jax.tree.map(lambda x: jnp.asarray(x), opt_state)
        # restore dtypes lost by npz roundtrip for int steps
        opt_state["step"] = jnp.asarray(opt_state["step"], jnp.int32)
        print(f"[resume] from step {start_step}", flush=True)
    else:
        params, _ = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = opt_init(params)

    logger = metrics_lib.JsonlLogger(args.log)
    timer = metrics_lib.StepTimer()
    losses = []
    for step in range(start_step, args.steps):
        batch = data_lib.train_batch(dcfg, step)
        with timer:
            params, opt_state, m = jitted(params, opt_state, batch)
            loss = float(m["loss"])
        losses.append(loss)
        line = logger.log(step, loss=loss, lr=m["lr"], grad_norm=m["grad_norm"],
                          step_time=timer.last, straggler=timer.is_straggler)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step}: loss {loss:.4f} ({timer.last:.2f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(
                args.ckpt_dir, step + 1,
                {"params": params, "opt": opt_state},
                blocking=False, meta={"arch": args.arch},
            )
        if args.preempt_after and (step + 1 - start_step) >= args.preempt_after:
            ckpt_lib.wait_pending()
            print(f"[preempt] hard exit at step {step + 1}", flush=True)
            os._exit(42)

    ckpt_lib.wait_pending()
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state})
    logger.close()
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
