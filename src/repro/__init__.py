"""repro: multi-density clustering hierarchies (RNG-HDBSCAN*) at pod scale."""

__version__ = "1.3.0"

__all__ = [
    "FittedModel",
    "MultiHDBSCAN",
    "Plan",
    "SelectionPolicy",
    "resolve_plan",
    "__version__",
]


def __getattr__(name):
    # lazy: `import repro` stays cheap; `repro.MultiHDBSCAN` pulls in jax
    if name in ("MultiHDBSCAN", "FittedModel", "SelectionPolicy"):
        from . import api

        return getattr(api, name)
    if name in ("Plan", "resolve_plan"):
        from . import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
