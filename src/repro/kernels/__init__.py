"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp twins).

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
dispatching wrappers), ref.py (pure-jnp oracles used by tests).
"""

from . import ops, ref
from .lune_filter import lune_filter
from .pairwise_topk import pairwise_topk

__all__ = ["ops", "ref", "lune_filter", "pairwise_topk"]
