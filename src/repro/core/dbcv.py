"""DBCV-style relative validity over an mrd MST (paper §I motivation).

The paper motivates multiple hierarchies by using an internal validation
measure (DBCV, Moulavi et al. 2014) to pick promising density levels across
hierarchies from different mpts.  Full DBCV recomputes all-points-core
distances; we implement the standard fast approximation computed directly on
the per-mpts mutual-reachability MST (the same simplification as the
reference hdbscan library's ``relative_validity_``):

  density sparseness DSC(Ci) = max internal MST edge of Ci
  density separation DSPC(Ci) = min MST edge leaving Ci (to any other cluster)
  V(Ci) = (DSPC - DSC) / max(DSPC, DSC);   DBCV = sum |Ci|/n * V(Ci)

Noise points are excluded.  Returns a value in [-1, 1]; higher is better.
"""

from __future__ import annotations

import numpy as np


def dbcv_relative_validity(
    ea: np.ndarray,
    eb: np.ndarray,
    w: np.ndarray,
    labels: np.ndarray,
) -> float:
    n = labels.shape[0]
    cl = np.unique(labels[labels >= 0])
    if len(cl) < 2:
        return -1.0

    la, lb = labels[ea], labels[eb]
    internal = (la == lb) & (la >= 0)
    crossing = (la != lb) & (la >= 0) & (lb >= 0)

    score = 0.0
    n_clustered = int(np.sum(labels >= 0))
    for c in cl:
        mask_int = internal & (la == c)
        dsc = float(w[mask_int].max()) if mask_int.any() else 0.0
        mask_out = crossing & ((la == c) | (lb == c))
        dspc = float(w[mask_out].min()) if mask_out.any() else np.inf
        denom = max(dspc, dsc)
        v = 0.0 if denom in (0.0, np.inf) and dspc is np.inf else (
            (dspc - dsc) / denom if denom > 0 else 0.0
        )
        if not np.isfinite(v):
            v = 1.0 if dsc == 0.0 else 0.0
        size_c = int(np.sum(labels == c))
        score += size_c / max(n_clustered, 1) * v
    return float(score)
