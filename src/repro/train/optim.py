"""Optimizers from scratch: AdamW (fp32 / bf16 / int8-blockwise states) and
Adafactor, plus schedules and global-norm clipping.

The int8-blockwise Adam state (per-256-element absmax scaling, bnb-style) is
what makes the 1T-param cell fit 512 x 16GB chips (DESIGN.md §8) — quantized
distributed optimizer state is a first-class config, not a hack.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

_BLOCK = 32  # small enough that sharded last dims stay block-divisible


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"   # float32 | bfloat16 | int8


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return cfg.lr * warm * cos


# ---------------------------------------------------------------------------
# int8 blockwise quantized tensors
# ---------------------------------------------------------------------------


def q8_compatible(shape) -> bool:
    """Blockwise int8 states quantize along the LAST dim so the quantized
    tensors keep the param's shape and therefore the param's SHARDING —
    a flat-block layout would force an unsharded regather at decode time
    (observed as 2.5 TiB/device f32 temps on the 1T config)."""
    return len(shape) >= 1 and shape[-1] % _BLOCK == 0


def _q8_zeros(shape):
    nb = shape[-1] // _BLOCK
    return {
        "q": jnp.zeros(shape, jnp.int8),
        "scale": jnp.zeros(shape[:-1] + (nb,), jnp.float32),
    }


def _q8_encode(x):
    shape = x.shape
    blocks = x.reshape(shape[:-1] + (shape[-1] // _BLOCK, _BLOCK)).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-20)).astype(jnp.int8)
    return {"q": q.reshape(shape), "scale": scale}


def _q8_decode(qt, shape):
    q = qt["q"].reshape(shape[:-1] + (shape[-1] // _BLOCK, _BLOCK))
    return (q.astype(jnp.float32) * qt["scale"][..., None]).reshape(shape)


def _is_q8(x):
    return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, cfg: OptConfig):
    def zeros_like_state(p):
        if cfg.state_dtype == "int8" and q8_compatible(p.shape):
            return _q8_zeros(p.shape)
        dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
        if cfg.state_dtype == "int8":
            dt = jnp.bfloat16  # q8-incompatible (small) params fall back
        return jnp.zeros(p.shape, dt)

    is_leaf = lambda x: hasattr(x, "shape")  # noqa: E731
    return {
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd_slice(p, g, m, v, ndim):
        gf = g.astype(jnp.float32)
        mf = _q8_decode(m, p.shape) if _is_q8(m) else m.astype(jnp.float32)
        # v is quantized in SQRT domain: linear-absmax int8 on raw v flushes
        # small entries in a block to zero, and m/(sqrt(0)+eps) explodes.
        # sqrt-domain storage compresses the dynamic range quadratically
        # (the same reason bnb 8-bit Adam uses a nonlinear quantile map).
        vf = _q8_decode(v, p.shape) ** 2 if _is_q8(v) else v.astype(jnp.float32)
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        if ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if _is_q8(m):
            return newp, _q8_encode(mf), _q8_encode(jnp.sqrt(vf))
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    def upd(p, g, m, v):
        # layer-stacked params update one layer-slice at a time (lax.map):
        # caps the f32 master/moment temporaries at 1/L of the tensor —
        # the difference between ~90 GiB and ~10 GiB peak on the 1T config.
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(
                lambda args: upd_slice(*args, ndim=p.ndim), (p, g, m, v)
            )
        return upd_slice(p, g, m, v, p.ndim)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.flatten(grads)[0]
    is_leaf = _is_q8
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_leaf)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_leaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment for >=2D params)
# ---------------------------------------------------------------------------


def adafactor_init(params, cfg: OptConfig):
    def zeros(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(zeros, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    d = 1.0 - cfg.b2

    def upd(p, g, f):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr = cfg.b2 * f["vr"] + d * jnp.mean(g2, axis=-1)
            vc = cfg.b2 * f["vc"] + d * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :] / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30
                )
            )
            update = gf / jnp.maximum(denom, 1e-30)
            newf = {"vr": vr, "vc": vc}
        else:
            v = cfg.b2 * f["v"] + d * g2
            update = gf / (jnp.sqrt(v) + cfg.eps)
            newf = {"v": v}
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return newp, newf

    leaves_def = jax.tree.structure(params)
    flat_p = jax.tree.leaves(params)
    flat_g = jax.tree.leaves(grads)
    flat_f = leaves_def.flatten_up_to(state["f"])
    out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = jax.tree.unflatten(leaves_def, [o[0] for o in out])
    new_f = jax.tree.unflatten(leaves_def, [o[1] for o in out])
    return new_p, {"f": new_f, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return (
            functools.partial(adamw_init, cfg=cfg),
            functools.partial(adamw_update, cfg=cfg),
        )
    if cfg.name == "adafactor":
        return (
            functools.partial(adafactor_init, cfg=cfg),
            functools.partial(adafactor_update, cfg=cfg),
        )
    raise ValueError(cfg.name)
