"""Hierarchy extraction: single-linkage vs scipy, condensed-tree semantics,
full-pipeline label equivalence (RNG path vs dense-matrix path), and the
vectorized extraction path vs the per-edge-loop reference."""

import numpy as np
import pytest

from repro.core import hierarchy, linkage, multi, ref as oref


def _random_spanning_tree(n, seed, dtype=np.float32, ties=False):
    rng = np.random.default_rng(seed)
    ea = np.array([rng.integers(0, i + 1) for i in range(n - 1)])
    eb = np.arange(1, n)
    if ties:
        w = rng.choice([0.5, 1.0, 1.5, 2.0], size=n - 1).astype(dtype)
    else:
        w = rng.uniform(0.1, 5.0, size=n - 1).astype(dtype)
    return ea, eb, w


def _assert_same_partition(a, b):
    """Cluster labels equal up to a bijective relabeling (noise is -1 = -1)."""
    assert ((a >= 0) == (b >= 0)).all()
    for c in np.unique(a[a >= 0]):
        members = np.unique(b[a == c])
        assert len(members) == 1, f"cluster {c} split into {members}"
    assert len(np.unique(a[a >= 0])) == len(np.unique(b[b >= 0]))


def test_single_linkage_vs_scipy_dense(gauss16d):
    from scipy.cluster.hierarchy import linkage as scipy_linkage
    from scipy.spatial.distance import squareform

    x = gauss16d[:100].astype(np.float64)
    m = oref.mrd_matrix(x, 4)
    ea, eb, w = oref.mst_edges_dense(m)
    Z_ours = hierarchy.single_linkage(ea, eb, w, len(x))
    Z_scipy = scipy_linkage(squareform(m, checks=False), method="single")
    np.testing.assert_allclose(np.sort(Z_ours[:, 2]), np.sort(Z_scipy[:, 2]), rtol=1e-9)
    # mrd ties are frequent; tied merges may interleave differently between
    # implementations (both trees valid).  Sizes must match where heights are
    # unique, and always at the top.
    order_o = np.argsort(Z_ours[:, 2], kind="stable")
    h_sorted = Z_ours[order_o, 2]
    uniq = np.concatenate([[True], np.diff(h_sorted) > 1e-12]) & np.concatenate(
        [np.diff(h_sorted) > 1e-12, [True]]
    )
    sizes_o = Z_ours[order_o, 3][uniq]
    sizes_s = Z_scipy[np.argsort(Z_scipy[:, 2], kind="stable"), 3][uniq]
    np.testing.assert_allclose(sizes_o, sizes_s)
    assert Z_ours[-1, 3] == Z_scipy[-1, 3] == len(x)


def test_batched_linkage_matches_reference_loop():
    """core.linkage (device, batched) == hierarchy.single_linkage (Python loop),
    row for row — the direct unit test that the vectorized construction is
    exact, including stable tie order."""
    n = 80
    eas, ebs, ws = zip(*[
        _random_spanning_tree(n, seed, ties=(seed % 2 == 0)) for seed in range(6)
    ])
    left, right, h, s = linkage.single_linkage_batch(
        np.stack(eas), np.stack(ebs), np.stack(ws), n=n
    )
    for row in range(6):
        Z_ref = hierarchy.single_linkage(eas[row], ebs[row], ws[row], n)
        Z_dev = linkage.linkage_to_Z(left[row], right[row], h[row], s[row])
        np.testing.assert_allclose(Z_dev, Z_ref, rtol=1e-6)


@pytest.mark.parametrize("mcs", [2, 3, 5, 25])
def test_vectorized_condense_matches_reference(mcs):
    """extract_condensed (pointer-doubling numpy) == condense_tree/labels_for
    (recursive reference): identical partitions, stabilities, and fall-out
    lambda multisets — including mcs > n/2 edge cases."""
    for seed in range(8):
        n = 40 + 7 * seed
        ea, eb, w = _random_spanning_tree(n, seed, ties=(seed % 3 == 0))
        labels_ref, tree_ref, stab_ref = hierarchy.hdbscan_labels(ea, eb, w, n, mcs)
        Z = hierarchy.single_linkage(ea, eb, w, n)
        labels_fast, tree_fast, stab_fast = hierarchy.extract_condensed(Z, n, mcs)
        _assert_same_partition(labels_ref, labels_fast)
        np.testing.assert_allclose(
            sorted(stab_ref.values()), sorted(stab_fast.values()), rtol=1e-9
        )
        for t_r, t_f in [(tree_ref, tree_fast)]:
            pr_r = t_r.child < n
            pr_f = t_f.child < n
            np.testing.assert_allclose(
                np.sort(t_r.lam[pr_r]), np.sort(t_f.lam[pr_f])
            )
            np.testing.assert_allclose(
                np.sort(t_r.child_size[~pr_r]), np.sort(t_f.child_size[~pr_f])
            )


def test_leaf_selection():
    """Leaf selection picks the fine-grained leaves: at least as many clusters
    as eom, and every eom cluster is a union of leaf clusters + noise."""
    rng = np.random.default_rng(3)
    x = np.concatenate([
        rng.normal((0, 0), 0.2, size=(40, 2)),
        rng.normal((1.2, 0), 0.2, size=(40, 2)),
        rng.normal((8, 8), 0.3, size=(40, 2)),
    ]).astype(np.float32)
    res_eom = multi.multi_hdbscan(x, 6, min_cluster_size=8)
    res_leaf = multi.multi_hdbscan(
        x, 6, min_cluster_size=8, cluster_selection_method="leaf"
    )
    h_eom = res_eom.hierarchies[-1]
    h_leaf = res_leaf.hierarchies[-1]
    assert h_leaf.n_clusters >= h_eom.n_clusters
    # leaf labels refine eom labels: no leaf cluster spans two eom clusters
    for c in np.unique(h_leaf.labels[h_leaf.labels >= 0]):
        parents = h_eom.labels[h_leaf.labels == c]
        assert len(np.unique(parents[parents >= 0])) <= 1


def test_condensed_tree_blobs(blobs):
    x, gt = blobs
    res = multi.multi_hdbscan(x, 12, variant="rng_star")
    h = [hh for hh in res.hierarchies if hh.mpts == 6][0]
    assert h.n_clusters == 3
    # each true blob maps to exactly one predicted cluster (majority)
    for blob_id, size in ((0, 80), (1, 80), (2, 60)):
        labs = h.labels[gt == blob_id]
        labs = labs[labs >= 0]
        vals, counts = np.unique(labs, return_counts=True)
        assert counts.max() / size > 0.9


def test_full_pipeline_equals_dense_pipeline(blobs):
    """Same extraction code fed by (a) the RNG MST and (b) the dense-matrix
    MST must produce identical labels (Cor. 1 at the *label* level)."""
    x, _ = blobs
    kmax = 10
    res = multi.multi_hdbscan(x, kmax, variant="rng")
    cd = oref.core_distances(x.astype(np.float64), kmax)
    for h in res.hierarchies[::3]:
        m = oref.mrd_matrix(x.astype(np.float64), h.mpts, cd)
        ea, eb, w = oref.mst_edges_dense(m)
        labels_dense, _, _ = hierarchy.hdbscan_labels(
            ea, eb, w, len(x), max(2, h.mpts)
        )
        # label ids may permute; compare partitions via contingency
        a, b = h.labels, labels_dense
        assert (a >= 0).sum() == (b >= 0).sum()
        for ca in np.unique(a[a >= 0]):
            members = b[a == ca]
            vals, counts = np.unique(members, return_counts=True)
            assert counts.max() / counts.sum() > 0.99


def test_stability_monotone_selection(blobs):
    x, _ = blobs
    res = multi.multi_hdbscan(x, 8, variant="rng_star")
    h = res.hierarchies[-1]
    stab = h.stability
    assert all(v >= 0 or np.isinf(v) for v in stab.values())
