"""Sharded checkpointing with atomic commit + auto-resume.

Layout:  <dir>/step_<N>/  arrays.npz  manifest.json   (+ .tmp staging)

Design points for fault tolerance at scale (DESIGN.md §6):
  * atomic commit: writes go to ``step_N.tmp`` and are renamed only after
    fsync — a killed writer never corrupts the latest checkpoint.
  * mesh-agnostic: arrays are saved at GLOBAL shape; restore re-shards onto
    whatever mesh the restart runs with (elastic re-scale = restart with a
    different mesh, nothing else changes).
  * async: ``save(..., blocking=False)`` hands the host copy to a writer
    thread so the train loop keeps stepping (one outstanding save max).
  * the data pipeline needs no state beyond `step` (see train/data.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


_pending: list[threading.Thread] = []


def save(ckpt_dir: str, step: int, state: dict, *, blocking: bool = True, meta: dict | None = None):
    """state: pytree of jax arrays (params, opt_state, ...)."""
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {"step": step, "keys": sorted(host.keys()), **(meta or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
    else:
        wait_pending()
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _pending.append(t)


def wait_pending():
    while _pending:
        _pending.pop().join()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, shardings=None) -> tuple[dict, int]:
    """Load a checkpoint; optionally re-shard with a pytree of NamedShardings."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        tree = _unflatten(
            {
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in _flatten(tree).items()
            }
        )
    return tree, step
