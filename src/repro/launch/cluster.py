import os

if os.environ.get("REPRO_CLUSTER_DRYRUN"):
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Clustering engine launcher: local runs + production-mesh dry-run.

Dry-run mode lowers the engine's three device data-planes on the production
mesh with ShapeDtypeStruct inputs (same contract as launch/dryrun.py):

  ring_knn      — the kmax-NN pass (paper Alg.1 lines 1-3)
  ring_lune     — the exact-RNG filter (lines 22-26)
  boruvka_range — the batched per-mpts MSTs (lines 31-32)

  REPRO_CLUSTER_DRYRUN=1 PYTHONPATH=src python -m repro.launch.cluster \
      --dryrun --n 4194304 --dim 64 --kmax 64 [--multi-pod]
"""

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np


def dryrun(n: int, dim: int, kmax: int, multi_pod: bool, out: str | None,
           bf16_tiles: bool = False, keep_hlo: bool = False, tag: str = ""):
    from repro.core import boruvka
    from repro.dist.cluster_parallel import ring_knn, ring_lune_count
    from repro.launch.mesh import make_production_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    dspec2 = NamedSharding(mesh, P(axes, None))
    dspec1 = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())

    dtype = jnp.bfloat16 if bf16_tiles else jnp.float32
    x_sds = jax.ShapeDtypeStruct((n, dim), dtype)
    m_edges = 8 * n  # RNG edge budget: ~8n edges (paper Fig 6 scale)
    results = {}

    # 1) ring kNN (the engine.Plan mesh path's kNN backend)
    knn_fn = jax.jit(
        lambda x: ring_knn(x, kmax, mesh),
        in_shardings=(dspec2,),
        out_shardings=(dspec2, dspec2),
    )
    lowered = knn_fn.lower(x_sds)
    compiled = lowered.compile()
    results["ring_knn"] = _report("ring_knn", compiled, n_chips)

    # 2) ring lune filter
    cd_sds = jax.ShapeDtypeStruct((n,), jnp.float32)
    e_sds = jax.ShapeDtypeStruct((m_edges,), jnp.int32)
    w_sds = jax.ShapeDtypeStruct((m_edges,), jnp.float32)
    lune_fn = jax.jit(
        lambda x, cd, ea, eb, w: ring_lune_count(x, cd, ea, eb, w, mesh),
        in_shardings=(dspec2, dspec1, dspec1, dspec1, dspec1),
        out_shardings=dspec1,
    )
    compiled = lune_fn.lower(x_sds, cd_sds, e_sds, e_sds, w_sds).compile()
    results["ring_lune"] = _report("ring_lune", compiled, n_chips)

    # 3) batched Boruvka over the mpts range: the R independent mpts rows
    # shard over the data axis (engine.Plan.mst_range semantics — including
    # its row padding to the axis size); the edge list (~8n ints) replicates
    data_ax = mesh.shape["data"]
    r_pad = -(-kmax // data_ax) * data_ax
    wr_sds = jax.ShapeDtypeStruct((r_pad, m_edges), jnp.float32)
    bor_fn = jax.jit(
        lambda ea, eb, w: boruvka.boruvka_mst_range(ea, eb, w, n=n),
        in_shardings=(repl, repl, NamedSharding(mesh, P("data", None))),
        out_shardings=NamedSharding(mesh, P("data", None)),
    )
    compiled = bor_fn.lower(e_sds, e_sds, wr_sds).compile()
    results["boruvka_range"] = _report("boruvka_range", compiled, n_chips)

    if out:
        os.makedirs(out, exist_ok=True)
        name = f"cluster__n{n}__d{dim}__k{kmax}__{'multi' if multi_pod else 'single'}{tag}"
        with open(os.path.join(out, name + ".json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


def _report(name: str, compiled, n_chips: int) -> dict:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks import hlo_utils

    ma = compiled.memory_analysis()
    stats = hlo_utils.analyze_hlo(compiled.as_text())
    terms = hlo_utils.roofline_terms(stats, n_chips)
    rec = {
        "kernel": name,
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "flops_per_device": stats.flops,
        "hbm_bytes_per_device": stats.bytes_hbm,
        "collective_bytes_per_device": stats.collective_bytes,
        "roofline": terms,
    }
    print(
        f"[{name}] temp {ma.temp_size_in_bytes/2**30:.2f} GiB/dev  "
        f"t_comp {terms['t_compute_s']*1e3:.1f}ms  t_mem {terms['t_memory_s']*1e3:.1f}ms  "
        f"t_coll {terms['t_collective_s']*1e3:.1f}ms -> {terms['dominant']}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--n", type=int, default=1 << 22)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--kmax", type=int, default=64)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--bf16-tiles", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun_cluster")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.dryrun:
        dryrun(args.n, args.dim, args.kmax, args.multi_pod, args.out,
               bf16_tiles=args.bf16_tiles, tag=args.tag)
    else:
        raise SystemExit("local mode: use examples/quickstart.py")


if __name__ == "__main__":
    main()
