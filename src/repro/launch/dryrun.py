import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

Proof obligations per the task:
  * 16x16 single-pod AND 2x16x16 multi-pod meshes compile for every cell
    (ShapeDtypeStruct inputs; nothing is allocated);
  * memory_analysis() printed (fits-in-HBM evidence);
  * cost_analysis() + loop-aware HLO stats recorded for §Roofline.

The XLA_FLAGS line above MUST run before any other import touches jax.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi_k2_1t_a32b \
      --shape train_4k --mesh single --rules '{"embed": null}'
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.dist import sharding as shardlib
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_init, get_model
from repro.train import optim as optim_mod
from repro.train.step import make_train_step

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    sh = SHAPES[shape_name]
    s_len, gb, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    i32 = jnp.int32
    f32 = jnp.float32

    if kind == "train":
        if cfg.arch == "encdec":
            dec = max(1, int(s_len * cfg.dec_seq_frac))
            return {
                "frames": jax.ShapeDtypeStruct((gb, s_len, cfg.frontend_dim), f32),
                "dec_tokens": jax.ShapeDtypeStruct((gb, dec), i32),
                "dec_labels": jax.ShapeDtypeStruct((gb, dec), i32),
                "dec_mask": jax.ShapeDtypeStruct((gb, dec), f32),
            }
        if cfg.frontend == "patches":
            n_text = s_len - cfg.frontend_tokens_4k
            return {
                "tokens": jax.ShapeDtypeStruct((gb, n_text), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (gb, cfg.frontend_tokens_4k, cfg.frontend_dim), f32),
                "labels": jax.ShapeDtypeStruct((gb, n_text), i32),
                "mask": jax.ShapeDtypeStruct((gb, n_text), f32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((gb, s_len), i32),
            "labels": jax.ShapeDtypeStruct((gb, s_len), i32),
            "mask": jax.ShapeDtypeStruct((gb, s_len), f32),
        }

    if kind == "prefill":
        if cfg.arch == "encdec":
            return {"frames": jax.ShapeDtypeStruct((gb, s_len, cfg.frontend_dim), f32)}
        if cfg.frontend == "patches":
            n_text = s_len - cfg.frontend_tokens_4k
            return {
                "tokens": jax.ShapeDtypeStruct((gb, n_text), i32),
                "patch_embeds": jax.ShapeDtypeStruct(
                    (gb, cfg.frontend_tokens_4k, cfg.frontend_dim), f32),
            }
        return {"tokens": jax.ShapeDtypeStruct((gb, s_len), i32)}

    # decode: cache + one token
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, gb, s_len))
    return {
        "cache": cache,
        "cur_tokens": jax.ShapeDtypeStruct((gb, 1), i32),
    }


def prefill_batch_for(cfg, shape_name):
    sh = SHAPES[shape_name]
    return min(sh["global_batch"], sh["global_batch"])


def build_step(cfg, shape_name: str, mesh, rules):
    """Returns (jitted_fn, example_args_SDS, donate) ready to .lower()."""
    kind = SHAPES[shape_name]["kind"]
    model = get_model(cfg)
    params_sds, specs = abstract_init(cfg)
    p_shard = shardlib.tree_shardings(specs, mesh, rules)

    def with_ctx(fn):
        def wrapped(*a, **k):
            with shardlib.activation_context(mesh, rules):
                return fn(*a, **k)
        return wrapped

    if kind == "train":
        opt_cfg = optim_mod.OptConfig(state_dtype=cfg.optimizer_state_dtype)
        opt_init, _ = optim_mod.make_optimizer(opt_cfg)
        opt_sds = jax.eval_shape(opt_init, params_sds)
        opt_shard = shardlib.opt_state_shardings(p_shard, opt_sds, mesh)
        batch_sds = input_specs(cfg, shape_name)
        b_shard = shardlib.batch_shardings(batch_sds, mesh)
        step_fn = with_ctx(make_train_step(cfg, opt_cfg))
        from jax.sharding import NamedSharding, PartitionSpec as P
        repl = NamedSharding(mesh, P())
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, {"loss": repl, "lr": repl, "grad_norm": repl}),
            donate_argnums=(0, 1),
        )
        return jitted, (params_sds, opt_sds, batch_sds)

    if kind == "prefill":
        ins = input_specs(cfg, shape_name)
        s_len = SHAPES[shape_name]["seq_len"]
        from jax.sharding import NamedSharding, PartitionSpec as P

        def prefill_fn(params, batch):
            if cfg.arch == "encdec":
                return model.prefill(params, cfg, batch["frames"], max_len=s_len)
            if cfg.frontend == "patches":
                return model.prefill(
                    params, cfg, batch["tokens"], max_len=s_len,
                    patch_embeds=batch["patch_embeds"])
            return model.prefill(params, cfg, batch["tokens"], max_len=s_len)

        b_shard = shardlib.batch_shardings(ins, mesh)
        cache_sds = jax.eval_shape(
            lambda p, b: prefill_fn(p, b), params_sds, ins)[1]
        c_shard = shardlib.cache_shardings(cache_sds, mesh)
        logits_shard = shardlib.batch_shardings(
            {"l": jax.ShapeDtypeStruct((SHAPES[shape_name]["global_batch"],), jnp.float32)},
            mesh)["l"]
        jitted = jax.jit(
            with_ctx(prefill_fn),
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )
        return jitted, (params_sds, ins)

    # decode
    ins = input_specs(cfg, shape_name)
    from jax.sharding import NamedSharding, PartitionSpec as P
    c_shard = shardlib.cache_shardings(ins["cache"], mesh)
    tok_shard = shardlib.batch_shardings({"t": ins["cur_tokens"]}, mesh)["t"]
    logits_shard = tok_shard

    def serve_step(params, cache, cur):
        return model.decode_step(params, cfg, cache, cur)

    jitted = jax.jit(
        with_ctx(serve_step),
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
    )
    return jitted, (params_sds, ins["cache"], ins["cur_tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
             rules_override=None, keep_hlo: bool = False, tag: str = ""):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.run_long_500k:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "skipped", "note": cfg.skip_note,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shardlib.resolve_rules(mesh, rules_override)
    t0 = time.time()
    jitted, args = build_step(cfg, shape_name, mesh, rules)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else (ca or {})
    hlo = compiled.as_text()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks import hlo_utils

    stats = hlo_utils.analyze_hlo(hlo)
    n_chips = 512 if multi_pod else 256
    terms = hlo_utils.roofline_terms(stats, n_chips)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        },
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        },
        "hlo_stats": {
            "flops_per_device": stats.flops,
            "hbm_bytes_per_device": stats.bytes_hbm,
            "collective_bytes_per_device": stats.collective_bytes,
            "collectives": stats.coll_bytes,
            "unknown_trip_counts": stats.unknown_trip_counts,
        },
        "roofline": terms,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape_name}__{rec['mesh']}{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        if keep_hlo:
            import gzip
            with gzip.open(os.path.join(out_dir, name + ".hlo.gz"), "wt") as f:
                f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--rules", default=None, help="JSON sharding-rule overrides")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch.replace("-", "_")]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    rules_override = json.loads(args.rules) if args.rules else None

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                try:
                    rec = run_cell(arch, shape, mp, args.out, rules_override,
                                   keep_hlo=args.keep_hlo, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "multi" if mp else "single",
                        "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                    }
                    traceback.print_exc()
                results.append(rec)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(
                        f"[OK] {label}: compile {rec['t_compile_s']}s  "
                        f"mem(temp) {rec['memory']['temp_bytes_per_device']/2**30:.2f} GiB/dev  "
                        f"t_comp {r['t_compute_s']*1e3:.2f}ms t_mem {r['t_memory_s']*1e3:.2f}ms "
                        f"t_coll {r['t_collective_s']*1e3:.2f}ms -> {r['dominant']}",
                        flush=True,
                    )
                else:
                    print(f"[{rec['status']}] {label}: {rec.get('note') or rec.get('error','')}",
                          flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n=== dry-run: {n_ok} ok / {n_skip} skipped / {n_fail} failed ===")
    if args.out:
        with open(os.path.join(args.out, "summary.json"), "w") as f:
            json.dump(results, f, indent=1)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
