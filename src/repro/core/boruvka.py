"""Minimum spanning trees in JAX: batched edge-list Boruvka + dense Prim.

``boruvka_mst``  — MST over an explicit edge list (the RNG).  Fully
vectorized label-propagation Boruvka: per-round two-phase scatter-min per
component (first the f32 weight, then — among weight-ties — the edge id),
symmetric-pair breaking, pointer-jumping union.  <= ceil(log2 n) rounds
inside ``lax.while_loop``.  The two-phase min is exactly a lexicographic
(w, edge-id) key, which makes the chosen MST unique => deterministic and
cycle-free even with duplicated mrd weights (which are COMMON: every edge
whose weight is a shared core distance ties).  (A single packed uint64 key
would need x64 mode; the two-phase form is also cheaper on TPU.)

``boruvka_mst_range`` — the paper's headline trick, TPU-shaped: ONE program
computes the MST for EVERY mpts value by vmapping over the (kmax, m) weight
matrix from ``mrd.reweight_all_mpts``.

``prim_dense_mst`` — the baseline HDBSCAN* MST over the *complete* mutual
reachability graph (never materialized; one mrd row per iteration), used by
the paper's comparison baseline and by tests as a same-framework oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnames=("n",))
def boruvka_mst(ea: jax.Array, eb: jax.Array, w: jax.Array, *, n: int):
    """MST of an undirected weighted graph given as an explicit edge list.

    Args:
      ea, eb: (m,) int32 endpoints.
      w: (m,) non-negative float32 weights.
      n: number of vertices (static).
    Returns:
      in_mst: (m,) bool mask of MST edges (n-1 True entries if connected).
    """
    m = w.shape[0]
    wf = w.astype(jnp.float32)
    idx = jnp.arange(m, dtype=jnp.int32)
    iota_n = jnp.arange(n)

    def cond(state):
        comp, in_mst, n_comp, progressed, rounds = state
        return (n_comp > 1) & progressed & (rounds < 64)

    def body(state):
        comp, in_mst, n_comp, _, rounds = state
        ca, cb = comp[ea], comp[eb]
        cross = ca != cb
        wc = jnp.where(cross, wf, jnp.inf)
        # phase 1: minimum cross-edge weight per component
        wmin = jnp.full((n,), jnp.inf, jnp.float32)
        wmin = wmin.at[ca].min(wc).at[cb].min(wc)
        # phase 2: among weight-ties, minimum edge id per component
        ia = jnp.where(cross & (wc == wmin[ca]), idx, m)
        ib = jnp.where(cross & (wc == wmin[cb]), idx, m)
        best_idx = jnp.full((n,), m, jnp.int32).at[ca].min(ia).at[cb].min(ib)
        has = best_idx < m
        eidx = jnp.where(has, best_idx, 0)
        # component each root connects to via its chosen edge
        pa = comp[ea[eidx]]
        pb = comp[eb[eidx]]
        other = jnp.where(pa == iota_n, pb, pa)
        parent = jnp.where(has, other, iota_n)
        # break mutual pairs: keep the smaller id as root
        parent = jnp.where((parent[parent] == iota_n) & (iota_n < parent), iota_n, parent)
        # pointer jumping to roots
        def pj_body(p):
            return p[p]

        def pj_cond(p):
            return jnp.any(p[p] != p)

        parent = jax.lax.while_loop(pj_cond, pj_body, parent)
        # mark chosen edges (scatter with drop for components w/o a choice)
        mark_idx = jnp.where(has, eidx, m)
        in_mst = in_mst.at[mark_idx].set(True, mode="drop")
        new_comp = parent[comp]
        new_n = jnp.sum(new_comp == iota_n).astype(jnp.int32)
        progressed = jnp.any(has)
        return new_comp, in_mst, new_n, progressed, rounds + 1

    init = (
        iota_n,
        jnp.zeros((m,), bool),
        jnp.int32(n),
        jnp.bool_(True),
        jnp.int32(0),
    )
    _, in_mst, n_comp, _, _ = jax.lax.while_loop(cond, body, init)
    return in_mst


@functools.partial(jax.jit, static_argnames=("n",))
def boruvka_mst_range(ea: jax.Array, eb: jax.Array, w_range: jax.Array, *, n: int):
    """MSTs for every mpts at once: w_range (R, m) -> in_mst (R, m) bool."""
    return jax.vmap(lambda w: boruvka_mst(ea, eb, w, n=n))(w_range)


@jax.jit
def prim_dense_mst(x: jax.Array, cd2_col: jax.Array):
    """Prim's MST over the implicit complete mrd graph for ONE mpts.

    This is the paper's (optimized) baseline unit of work: O(n^2) mrd
    evaluations, one row per iteration, nothing materialized.

    Returns (parent_src (n,), w2 (n,)): for each vertex != start, the MST edge
    (parent_src[v], v) with squared mrd weight w2[v]; w2[start] = 0.
    """
    n, _ = x.shape
    xf = x.astype(jnp.float32)

    def mrd_row(u):
        diff = xf - xf[u]
        d2 = jnp.sum(diff * diff, axis=-1)  # diff form: no cancellation noise
        return jnp.maximum(jnp.maximum(cd2_col[u], cd2_col), d2)

    def body(i, state):
        in_tree, best_w2, best_src, last = state
        row = mrd_row(last)
        better = (row < best_w2) & ~in_tree
        best_w2 = jnp.where(better, row, best_w2)
        best_src = jnp.where(better, last, best_src)
        pick = jnp.argmin(jnp.where(in_tree, jnp.inf, best_w2))
        in_tree = in_tree.at[pick].set(True)
        return in_tree, best_w2, best_src, pick

    in_tree = jnp.zeros((n,), bool).at[0].set(True)
    best_w2 = jnp.full((n,), jnp.inf, jnp.float32).at[0].set(0.0)
    best_src = jnp.zeros((n,), jnp.int32)
    state = (in_tree, best_w2, best_src, jnp.int32(0))
    in_tree, best_w2, best_src, _ = jax.lax.fori_loop(0, n - 1, body, state)
    return best_src, jnp.where(jnp.arange(n) == 0, 0.0, best_w2)
