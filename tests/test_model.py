"""FittedModel artifact layer: save/load round-trips (bit-identical labels,
hierarchies, predictions across backends), corruption/schema/config error
handling, SelectionPolicy views (leaf/eom/epsilon), and exemplars."""

import json
import warnings

import numpy as np
import pytest

from repro.api import (
    ArtifactError,
    Clustering,
    FittedModel,
    MultiHDBSCAN,
    SelectionPolicy,
)

KMAX = 8


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(7)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(80, 2)),
        rng.normal((4, 0), 0.5, size=(80, 2)),
        rng.normal((2, 4), 0.4, size=(60, 2)),
        rng.uniform(-2, 6, size=(20, 2)),
    ]).astype(np.float32)
    return x


@pytest.fixture(scope="module")
def model(dataset):
    return FittedModel.fit(dataset, KMAX)


@pytest.fixture(scope="module")
def queries(dataset):
    rng = np.random.default_rng(8)
    return (dataset[rng.choice(len(dataset), 12)]
            + rng.normal(0, 0.05, (12, 2))).astype(np.float32)


def _resave_with_header(src_path, dst_path, mutate):
    """Rewrite an artifact with a hand-edited header (tamper harness)."""
    with np.load(src_path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    header = json.loads(arrays.pop("__header__").tobytes().decode())
    mutate(header)
    with open(dst_path, "wb") as f:
        np.savez(
            f,
            __header__=np.frombuffer(json.dumps(header).encode(), np.uint8),
            **arrays,
        )


# -- round trips -------------------------------------------------------------


def test_save_load_bit_identical(model, queries, tmp_path):
    """The acceptance criterion: a loaded artifact answers every fitted mpts
    with bit-identical labels, hierarchies, and predictions — zero refit."""
    path = model.save(str(tmp_path / "m.npz"))
    loaded = FittedModel.load(path)

    assert loaded.config == model.config
    assert loaded.config_hash == model.config_hash
    assert loaded.mpts_values == model.mpts_values
    assert loaded.default_policy == model.default_policy
    assert loaded.n_graph_edges == model.n_graph_edges

    for mpts in model.mpts_values:
        a, b = model.select(mpts), loaded.select(mpts)
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.probabilities, b.probabilities)
        np.testing.assert_array_equal(a.lambdas, b.lambdas)
        np.testing.assert_array_equal(a.condensed_tree.parent, b.condensed_tree.parent)
        np.testing.assert_array_equal(a.condensed_tree.child, b.condensed_tree.child)
        np.testing.assert_array_equal(a.condensed_tree.lam, b.condensed_tree.lam)
        assert a.stability == b.stability
        assert a.selected == b.selected

    want = model.approximate_predict(queries)
    got = loaded.approximate_predict(queries)
    np.testing.assert_array_equal(want.labels, got.labels)
    np.testing.assert_array_equal(want.probabilities, got.probabilities)
    np.testing.assert_array_equal(want.lambdas, got.lambdas)
    np.testing.assert_array_equal(want.neighbors, got.neighbors)


def test_save_load_roundtrip_every_backend(dataset, queries, tmp_path):
    """Artifacts are backend-portable: a model fitted under each backend
    round-trips to the same labels and predictions."""
    import jax

    backends = ["ref", "jnp"]
    backends.append("pallas" if jax.default_backend() == "tpu" else "pallas_interpret")
    for b in backends:
        m = FittedModel.fit(dataset, KMAX, backend=b)
        path = m.save(str(tmp_path / f"m_{b}.npz"))
        loaded = FittedModel.load(path, backend=b)
        for mpts in (2, KMAX // 2, KMAX):
            np.testing.assert_array_equal(
                m.select(mpts).labels, loaded.select(mpts).labels, err_msg=b
            )
        lab, prob = m.approximate_predict(queries, mpts=KMAX // 2)
        lab2, prob2 = loaded.approximate_predict(queries, mpts=KMAX // 2)
        np.testing.assert_array_equal(lab, lab2, err_msg=b)
        np.testing.assert_array_equal(prob, prob2, err_msg=b)


def test_estimator_save_and_roundtrip(dataset, tmp_path):
    """est.save(path) is FittedModel.save; a load serves the same labels."""
    est = MultiHDBSCAN(kmax=KMAX, min_cluster_size=10).fit(dataset)
    path = est.save(str(tmp_path / "est.npz"))
    loaded = FittedModel.load(path)
    # the estimator's selection configuration rides along as default policy
    assert loaded.default_policy.min_cluster_size == 10
    np.testing.assert_array_equal(
        est.model_.select(KMAX).labels, loaded.select(KMAX).labels
    )


# -- error handling ----------------------------------------------------------


def test_load_rejects_garbage_and_truncation(model, tmp_path):
    garbage = tmp_path / "garbage.npz"
    garbage.write_bytes(b"this is not an npz file at all")
    with pytest.raises(ArtifactError, match="not a readable FittedModel"):
        FittedModel.load(str(garbage))

    path = model.save(str(tmp_path / "trunc.npz"))
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(ArtifactError):
        FittedModel.load(path)


def test_load_rejects_foreign_npz(tmp_path):
    foreign = tmp_path / "foreign.npz"
    np.savez(foreign, a=np.arange(3))
    with pytest.raises(ArtifactError, match="__header__"):
        FittedModel.load(str(foreign))


def test_load_rejects_schema_version_mismatch(model, tmp_path):
    src = model.save(str(tmp_path / "ok.npz"))
    bad = str(tmp_path / "future.npz")

    def bump(header):
        header["schema_version"] = 999

    _resave_with_header(src, bad, bump)
    with pytest.raises(ArtifactError, match="schema version 999"):
        FittedModel.load(bad)


def test_load_rejects_config_tampering(model, tmp_path):
    """A hand-edited config (kmax changed) no longer matches its hash."""
    src = model.save(str(tmp_path / "ok.npz"))
    bad = str(tmp_path / "tampered.npz")

    def tamper(header):
        header["config"]["kmax"] = 99

    _resave_with_header(src, bad, tamper)
    with pytest.raises(ArtifactError, match="config fingerprint mismatch"):
        FittedModel.load(bad)


def test_load_rejects_wrong_expected_config(model, tmp_path):
    """Deployments can pin the workload they were built for."""
    path = model.save(str(tmp_path / "m.npz"))
    assert FittedModel.load(
        path, expect_config_hash=model.config_hash
    ).config_hash == model.config_hash
    with pytest.raises(ArtifactError, match="does not match the expected"):
        FittedModel.load(path, expect_config_hash="0" * 16)


def test_load_rejects_missing_arrays(model, tmp_path):
    src = model.save(str(tmp_path / "ok.npz"))
    with np.load(src, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    arrays.pop("mst_ea")
    hollow = tmp_path / "hollow.npz"
    with open(hollow, "wb") as f:
        np.savez(f, **arrays)
    with pytest.raises(ArtifactError, match="missing arrays"):
        FittedModel.load(str(hollow))


# -- selection policies ------------------------------------------------------


def test_policy_validation():
    with pytest.raises(ValueError, match="method"):
        SelectionPolicy(method="bogus")
    with pytest.raises(ValueError, match="epsilon"):
        SelectionPolicy(epsilon=-0.5)
    with pytest.raises(ValueError, match="epsilon"):
        SelectionPolicy(epsilon=float("nan"))
    with pytest.raises(ValueError, match="min_cluster_size"):
        SelectionPolicy(min_cluster_size=1)
    p = SelectionPolicy(method="leaf", epsilon=0.5)
    assert p.replace(epsilon=0.0) == SelectionPolicy(method="leaf")
    assert SelectionPolicy.from_dict(p.to_dict()) == p
    assert hash(p) == hash(SelectionPolicy(method="leaf", epsilon=0.5))


def test_policy_views_cached_separately(model):
    """(mpts, policy) pairs key the cache: different views coexist without
    re-extraction, same view returns the same arrays."""
    eom = model.select(KMAX)
    leaf = model.select(KMAX, SelectionPolicy(method="leaf"))
    assert leaf.n_clusters >= eom.n_clusters
    assert model.select(KMAX).labels is eom.labels  # cache hit, same object
    # every leaf cluster sits inside one eom cluster
    for c in np.unique(leaf.labels[leaf.labels >= 0]):
        parents = eom.labels[leaf.labels == c]
        assert len(np.unique(parents[parents >= 0])) <= 1


def test_epsilon_merges_fine_clusters(model):
    """Malzer & Baum hybrid: epsilon coarsens the leaf partition, and each
    base cluster lands in exactly one epsilon-cluster (pure merging)."""
    base = model.select(3, SelectionPolicy(method="leaf"))
    prev = base.n_clusters
    assert model.select(3, SelectionPolicy(method="leaf", epsilon=0.0)).labels is base.labels
    for eps in (0.3, 0.8, 2.0):
        merged = model.select(3, SelectionPolicy(method="leaf", epsilon=eps))
        assert merged.n_clusters <= prev
        for c in np.unique(base.labels[base.labels >= 0]):
            targets = merged.labels[base.labels == c]
            targets = targets[targets >= 0]
            assert len(np.unique(targets)) <= 1, (eps, c)
        prev = merged.n_clusters
    # epsilon applies to eom selection too
    eom_eps = model.select(3, policy=SelectionPolicy(epsilon=2.0))
    assert eom_eps.n_clusters <= model.select(3).n_clusters


def test_select_all_matches_per_level(model):
    views = model.select_all()
    assert [v.mpts for v in views] == model.mpts_values
    for v in views:
        assert isinstance(v, Clustering)
        np.testing.assert_array_equal(v.labels, model.select(v.mpts).labels)


def test_exemplars_are_core_members(model):
    """Exemplars: non-empty per cluster, members of their own cluster, and
    at least as strongly attached as the average member."""
    for policy in (None, SelectionPolicy(method="leaf")):
        c = model.select(KMAX, policy)
        assert len(c.exemplars) == c.n_clusters
        for label, ex in enumerate(c.exemplars):
            assert len(ex) > 0
            assert np.all(c.labels[ex] == label)
            assert c.probabilities[ex].mean() >= c.probabilities[c.labels == label].mean()


def test_lru_bound_on_policy_cache(dataset):
    model = FittedModel.fit(dataset, KMAX, max_cached_hierarchies=2)
    model.select(2)
    model.select(3)
    model.select(3, SelectionPolicy(method="leaf"))  # evicts (2, eom)
    keys = list(model._cache)
    assert len(keys) == 2 and keys[0] == (3, model.default_policy)
    lab = model.select(2).labels  # re-extracts transparently
    assert lab.shape == (len(dataset),)


def test_clustering_view_shares_no_mutable_state(model):
    c = model.select(KMAX)
    assert c.condensed_tree is model.hierarchy(KMAX).condensed
    assert c.mpts == KMAX and c.policy == model.default_policy
    r = repr(c)
    assert "Clustering" in r and "mpts=8" in r


def test_deprecated_estimator_shims_match_model(dataset):
    """The legacy per-level accessors answer identically and warn."""
    est = MultiHDBSCAN(kmax=KMAX).fit(dataset)
    with pytest.warns(FutureWarning, match="labels_for"):
        lab = est.labels_for(KMAX)
    np.testing.assert_array_equal(lab, est.model_.select(KMAX).labels)
    with pytest.warns(FutureWarning, match="membership_for"):
        m = est.membership_for(KMAX)
    np.testing.assert_array_equal(m.probabilities, est.model_.select(KMAX).probabilities)
    with pytest.warns(FutureWarning, match="hierarchy_for"):
        h = est.hierarchy_for(KMAX)
    assert h is est.model_.hierarchy(KMAX)
    # the new surface is warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", FutureWarning)
        est.model_.select(KMAX).labels
        est.select(KMAX).probabilities
        est.approximate_predict(dataset[:3], mpts=KMAX)
