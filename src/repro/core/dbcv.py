"""DBCV-style relative validity over an mrd MST (paper §I motivation).

The paper motivates multiple hierarchies by using an internal validation
measure (DBCV, Moulavi et al. 2014) to pick promising density levels across
hierarchies from different mpts.  Full DBCV recomputes all-points-core
distances; we implement the standard fast approximation computed directly on
the per-mpts mutual-reachability MST (the same simplification as the
reference hdbscan library's ``relative_validity_``):

  density sparseness DSC(Ci) = max internal MST edge of Ci
  density separation DSPC(Ci) = min MST edge leaving Ci (to any other cluster)
  V(Ci) = (DSPC - DSC) / max(DSPC, DSC);   DBCV = sum |Ci|/n * V(Ci)

Noise points are excluded.  Returns a value in [-1, 1]; higher is better.
"""

from __future__ import annotations

import numpy as np


def dbcv_relative_validity(
    ea: np.ndarray,
    eb: np.ndarray,
    w: np.ndarray,
    labels: np.ndarray,
) -> float:
    """DBCV relative validity of a labelling over its mrd MST.

    Vectorized over clusters (scatter-max for DSC, scatter-min for DSPC; no
    per-cluster edge scans), with the degenerate regimes handled by explicit
    ``np.isinf`` cases rather than value comparisons — an earlier version
    guarded the missing-crossing-edge branch with ``dspc is np.inf``, a
    float *identity* check that is False for any computed inf (e.g. an inf
    edge weight flowing through ``min``), silently misrouting those clusters
    through the generic formula (inf/inf -> nan).

    Cases, per cluster ``Ci`` (V in [-1, 1], DBCV = sum |Ci|/n * V):
      * DSPC infinite (no crossing MST edge at all — e.g. every path to the
        other clusters runs through noise points — or only inf-weight
        crossing edges): the cluster is unboundedly separated, V = +1.
      * DSC infinite (an inf-weight internal edge) with finite DSPC:
        unboundedly sparse, V = -1.
      * both infinite: the two degeneracies cancel, V = 0.
      * DSPC == DSC == 0 (duplicate-point cluster touching a duplicate
        crossing edge): no density contrast either way, V = 0.
      * otherwise the standard (DSPC - DSC) / max(DSPC, DSC).
    """
    cl = np.unique(labels[labels >= 0])
    if len(cl) < 2:
        return -1.0
    K = len(cl)

    la, lb = labels[ea], labels[eb]
    internal = (la == lb) & (la >= 0)
    crossing = (la != lb) & (la >= 0) & (lb >= 0)

    dsc = np.zeros(K)
    np.maximum.at(dsc, np.searchsorted(cl, la[internal]), w[internal])
    dspc = np.full(K, np.inf)
    cw = w[crossing]
    np.minimum.at(dspc, np.searchsorted(cl, la[crossing]), cw)
    np.minimum.at(dspc, np.searchsorted(cl, lb[crossing]), cw)

    denom = np.maximum(dspc, dsc)
    with np.errstate(invalid="ignore"):
        v = np.where(
            np.isinf(dspc) & np.isinf(dsc), 0.0,
            np.where(
                np.isinf(dspc), 1.0,
                np.where(
                    np.isinf(dsc), -1.0,
                    np.divide(dspc - dsc, denom, out=np.zeros(K), where=denom > 0),
                ),
            ),
        )

    sizes = np.bincount(np.searchsorted(cl, labels[labels >= 0]), minlength=K)
    n_clustered = int(sizes.sum())
    return float(np.sum(sizes / max(n_clustered, 1) * v))
