"""Serve clustering queries from one fitted multi-density state.

Fits once, saves the fitted state as an artifact, boots a serve worker
from the artifact (the refit-free scale-out path), then drives concurrent
out-of-sample prediction traffic through the micro-batching
ClusterServeEngine and prints the latency profile.

  PYTHONPATH=src python examples/serve_clusters.py
"""

import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, "src")

import numpy as np

from repro.api import FittedModel, SelectionPolicy
from repro.serve import ClusterServeEngine


def main():
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.normal((0, 0), 0.3, size=(500, 2)),
        rng.normal((4, 0), 0.5, size=(500, 2)),
        rng.normal((2, 4), 0.8, size=(300, 2)),
    ]).astype(np.float32)

    # fit ONCE, persist the artifact: every serve worker loads it in ~ms
    t0 = time.monotonic()
    model = FittedModel.fit(x, kmax=16)
    t_fit = time.monotonic() - t0
    path = os.path.join(tempfile.mkdtemp(), "clusters.fitted.npz")
    model.save(path)
    t0 = time.monotonic()
    with ClusterServeEngine.load(
        path, expect_config_hash=model.config_hash
    ) as eng:
        t_boot = time.monotonic() - t0
        print(f"fit {t_fit:.2f}s once -> worker boots from "
              f"{os.path.getsize(path) / 1e6:.1f} MB artifact in {t_boot * 1e3:.0f} ms")

        # a burst of concurrent single-query clients, mixed density levels
        queries = x[rng.choice(len(x), size=128)] + rng.normal(0, 0.05, (128, 2)).astype(np.float32)
        results = {}

        def client(i):
            results[i] = eng.predict(queries[i], mpts=int(4 + 4 * (i % 4)))

        threads = [threading.Thread(target=client, args=(i,)) for i in range(128)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        labeled = sum(1 for lab, _ in results.values() if lab[0] >= 0)
        print(f"128 concurrent queries: {labeled} assigned to clusters")
        leaf = SelectionPolicy(method="leaf")
        hybrid = SelectionPolicy(method="leaf", epsilon=0.8)
        print("per-request selection policy:",
              f"eom -> {eng.labels(8).max() + 1} clusters,",
              f"leaf -> {eng.labels(8, policy=leaf).max() + 1},",
              f"leaf+eps(0.8) -> {eng.labels(8, policy=hybrid).max() + 1}")
        print("engine stats:", eng.stats())


if __name__ == "__main__":
    main()
