"""Paper experiment sweeps (Figs 5/6/7, Table II), scaled to this host.

The paper sweeps 16k-1M points / 2-128 dims / kmax 2-128 on a 64GB Java
setup; this harness runs the same GRID SHAPE at host-appropriate sizes (the
headline metric — the ratio of kmax-hierarchies' cost to one hierarchy's —
is scale-free).  Every row reports runtime per phase, edge counts for
G_mpts vs RNG**, RNG*, RNG, and the Fig-7 ratio.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import hierarchy, multi


def _dataset(n: int, d: int, seed: int = 0) -> np.ndarray:
    """Handl-Knowles-style clustered generator (paper's data family)."""
    rng = np.random.default_rng(seed)
    n_clusters = max(4, min(20, n // 800))
    centers = rng.uniform(-10, 10, size=(n_clusters, d))
    sizes = rng.multinomial(n, np.ones(n_clusters) / n_clusters)
    parts = [
        rng.normal(c, rng.uniform(0.5, 1.5), size=(s, d))
        for c, s in zip(centers, sizes)
    ]
    return np.concatenate(parts).astype(np.float32)


def run_cell(n: int, d: int, kmax: int, variants=("rng_ss", "rng_star", "rng"),
             with_baseline: bool = True, seed: int = 0):
    """One sweep cell. Returns list of result dicts."""
    x = _dataset(n, d, seed)
    rows = []
    mpts = list(range(2, kmax + 1))
    for v in variants:
        t0 = time.monotonic()
        res = multi.multi_hdbscan(x, kmax, variant=v, compute_hierarchies=True)
        wall = time.monotonic() - t0
        rows.append({
            "bench": "sweep", "n": n, "d": d, "kmax": kmax, "method": v,
            "wall_s": round(wall, 3),
            **{f"t_{k}": round(tv, 3) for k, tv in res.timings.items()},
            "edges": int(len(res.graph.edges)),
            "edges_complete": n * (n - 1) // 2,
            "wspd_pairs": res.graph.stats.get("n_wspd_pairs", -1),
        })
    if with_baseline:
        t0 = time.monotonic()
        _, tb = multi.hdbscan_baseline(x, mpts, kmax=kmax)
        rows.append({
            "bench": "sweep", "n": n, "d": d, "kmax": kmax, "method": "baseline",
            "wall_s": round(time.monotonic() - t0, 3),
            **{f"t_{k}": round(tv, 3) for k, tv in tb.items()},
            "edges": n * (n - 1) // 2,
            "edges_complete": n * (n - 1) // 2,
        })
        # Fig 7 denominator: ONE hierarchy at mpts=kmax via the baseline
        t0 = time.monotonic()
        multi.hdbscan_baseline(x, [kmax], kmax=kmax)
        one = time.monotonic() - t0
        for r in rows:
            r["ratio_vs_one"] = round(r["wall_s"] / max(one, 1e-9), 2)
    return rows


def size_sweep(sizes=(1000, 2000, 4000, 8000), d=8, kmax=16):
    """Fig 5a / 6a."""
    out = []
    for n in sizes:
        out += run_cell(n, d, kmax)
    return out


def dim_sweep(dims=(2, 4, 8, 16, 32), n=4000, kmax=16):
    """Fig 5b / 6b."""
    out = []
    for d in dims:
        out += run_cell(n, d, kmax)
    return out


def kmax_sweep(kmaxes=(2, 4, 8, 16, 32, 64), n=4000, d=8):
    """Fig 5c / 6c + Table II + Fig 7."""
    out = []
    for k in kmaxes:
        out += run_cell(n, d, k)
    return out


def extraction_sweep(n=2000, d=8, kmax=16, seed=0):
    """Extraction phase only: batched device linkage + vectorized condense
    vs the legacy per-edge Python union-find loop, same MSTs in, same labels
    out.  This is the hierarchy row the paper folds into "total" — batching
    it keeps the whole pipeline device-shaped.
    """
    x = _dataset(n, d, seed)
    msts = multi.fit_msts(x, kmax)
    rows = []

    t0 = time.monotonic()
    hs, timings = multi.extract_hierarchies(msts)
    t_batched = time.monotonic() - t0
    rows.append({
        "bench": "extraction", "n": n, "kmax": kmax, "method": "batched",
        "wall_s": round(t_batched, 4),
        "t_linkage": round(timings["hierarchy_linkage"], 4),
        "t_condense": round(timings["hierarchy_condense"], 4),
    })

    t0 = time.monotonic()
    legacy = []
    for row, mpts in enumerate(msts.mpts_values):
        labels, _, _ = hierarchy.hdbscan_labels(
            msts.mst_ea[row], msts.mst_eb[row], msts.mst_w[row],
            msts.n, max(2, mpts),
        )
        legacy.append(labels)
    t_legacy = time.monotonic() - t0
    rows.append({
        "bench": "extraction", "n": n, "kmax": kmax, "method": "legacy_loop",
        "wall_s": round(t_legacy, 4),
    })
    for r in rows:
        r["speedup_vs_loop"] = round(t_legacy / max(r["wall_s"], 1e-9), 2)
    # both paths must agree (sanity, not timing): same cluster counts
    for h, lab in zip(hs, legacy):
        assert int(lab.max()) == int(h.labels.max()), "extraction paths diverge"
    return rows
