"""Symmetric Bichromatic Closest Neighbors over WSPD pairs (paper §IV-E, Fig 4).

For each well-separated pair (A, B), connect a in A and b in B iff b is a's
closest point in B AND a is b's closest point in A, w.r.t. ``mrd_kmax``.  The
union over all pairs is the RNG** supergraph.

Device data-plane: pairs are bucketed by padded (|A|, |B|) size class and each
size tier is ONE jitted device program — a fixed-shape (chunk, amax, bmax)
mrd tile + masked argmin, dispatched over the tier's chunks with the results
kept on device.  ``sbcn_candidates`` returns the whole candidate set as jax
arrays (``lo``/``hi`` endpoint arrays, lexicographically sorted, duplicates
masked out), so the downstream filter cascade can stay device-resident; the
``sbcn_edges`` wrapper is the host-compacted (m, 2) numpy view.

Tie-robustness: ALL tied row/column minima are kept (a superset of the
single-argmin SBCN), which preserves the RNG-superset property under
duplicate mrd values.

Oversized pairs (padded |A|*|B| above the bucket cap) are evaluated with a
row-chunked two-pass min-reduction: peak memory is O(row_chunk * |B|)
regardless of |A|.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_PAIR_ELEM_CAP = 1 << 18  # max padded |A|*|B| handled by the batched path
_TILE_ELEMS = 1 << 22     # elements per tier-program chunk
_ROW_CHUNK = 2048         # row chunk for oversized pairs
_SENTINEL = np.int32(np.iinfo(np.int32).max)  # invalid / duplicate slot marker

_EPS = 64.0 * 1.1920929e-07


def _mutual_mask(x, cd2k, a_idx, b_idx):
    """SBCN mask for one padded bucket chunk.

    a_idx: (P, amax) int32 point ids padded with -1; likewise b_idx.
    Returns (P, amax, bmax) bool mask of SBCN edges.
    """
    xa = x[a_idx]                                  # (P, amax, d)
    xb = x[b_idx]
    an = jnp.sum(xa.astype(jnp.float32) ** 2, -1)
    bn = jnp.sum(xb.astype(jnp.float32) ** 2, -1)
    d2 = (
        an[:, :, None]
        + bn[:, None, :]
        - 2.0 * jnp.einsum("pad,pbd->pab", xa.astype(jnp.float32), xb.astype(jnp.float32))
    )
    d2 = jnp.maximum(d2, 0.0)
    mrd2 = jnp.maximum(jnp.maximum(cd2k[a_idx][:, :, None], cd2k[b_idx][:, None, :]), d2)
    invalid = (a_idx < 0)[:, :, None] | (b_idx < 0)[:, None, :]
    mrd2 = jnp.where(invalid, jnp.inf, mrd2)
    # Norm-scaled tolerance: near-ties (incl. matmul-form cancellation noise)
    # are ALL kept as mutual-nearest candidates — only ever adds edges.
    tol = jnp.float32(_EPS) * (an[:, :, None] + bn[:, None, :])
    row_min = jnp.min(mrd2, axis=2, keepdims=True)     # (P, amax, 1)
    col_min = jnp.min(mrd2, axis=1, keepdims=True)     # (P, 1, bmax)
    return (
        (mrd2 <= row_min + tol)
        & (mrd2 <= col_min + tol)
        & ~invalid
        & jnp.isfinite(mrd2)
    )


@jax.jit
def _sbcn_tier_chunk(x, cd2k, a_idx, b_idx):
    """One fixed-shape tier chunk -> flat (lo, hi) candidate slots.

    This is THE device program for a size tier: compiled once per
    (chunk, amax, bmax) shape, dispatched over the tier's chunks, outputs
    stay on device.  Non-edge slots hold the sentinel.
    """
    mutual = _mutual_mask(x, cd2k, a_idx, b_idx)
    ga = jnp.broadcast_to(a_idx[:, :, None], mutual.shape)
    gb = jnp.broadcast_to(b_idx[:, None, :], mutual.shape)
    lo = jnp.where(mutual, jnp.minimum(ga, gb), _SENTINEL)
    hi = jnp.where(mutual, jnp.maximum(ga, gb), _SENTINEL)
    return lo.reshape(-1), hi.reshape(-1)


@functools.partial(jax.jit, static_argnames=("row_chunk",))
def _sbcn_large(x, cd2k, a_idx, b_idx, *, row_chunk: int = _ROW_CHUNK):
    """Row-chunked SBCN for one oversized pair. a_idx (na,), b_idx (nb,).

    Two passes over row chunks of the (na, nb) mrd tile — pass 1 reduces the
    column minima, pass 2 re-evaluates each chunk against the global minima —
    so peak memory is O(row_chunk * nb) float32, never the full tile.
    Returns the (na, nb) bool mutual mask.
    """
    na, nb = a_idx.shape[0], b_idx.shape[0]
    rc = min(row_chunk, na)
    na_pad = -(-na // rc) * rc
    a_pad = jnp.full((na_pad,), -1, a_idx.dtype).at[:na].set(a_idx)

    xb = x[b_idx].astype(jnp.float32)
    cdb = cd2k[b_idx]
    bnorm = jnp.sum(xb * xb, -1)

    def mrd_chunk(ac):
        xa = x[ac].astype(jnp.float32)
        anorm = jnp.sum(xa * xa, -1)
        d2 = anorm[:, None] + bnorm[None, :] - 2.0 * xa @ xb.T
        m = jnp.maximum(jnp.maximum(cd2k[ac][:, None], cdb[None, :]), jnp.maximum(d2, 0.0))
        m = jnp.where((ac < 0)[:, None], jnp.inf, m)
        tol = jnp.float32(_EPS) * (anorm[:, None] + bnorm[None, :])
        return m, tol

    chunks = a_pad.reshape(-1, rc)

    def pass1(ac):
        m, _ = mrd_chunk(ac)
        return jnp.min(m, axis=0)                      # (nb,) partial col min

    col_min = jnp.min(jax.lax.map(pass1, chunks), axis=0)[None, :]

    def pass2(ac):
        m, tol = mrd_chunk(ac)
        row_min = jnp.min(m, axis=1, keepdims=True)
        return (m <= row_min + tol) & (m <= col_min + tol) & jnp.isfinite(m)

    mask = jax.lax.map(pass2, chunks).reshape(na_pad, nb)
    return mask[:na]


def _dedup_sorted(lo, hi):
    """Lexicographically sort (lo, hi) slots; mask duplicate / sentinel slots.

    Returns (lo, hi, keep): sorted endpoint arrays and a bool mask that is
    True exactly on the first occurrence of each real edge.
    """
    lo, hi = jax.lax.sort((lo, hi), dimension=0, num_keys=2)
    valid = lo != _SENTINEL
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])]
    )
    return lo, hi, valid & first


@jax.jit
def _count_real(lo):
    return jnp.sum(lo != _SENTINEL)


@jax.jit
def _compact_slots(lo, hi, out_lo, out_hi):
    """Scatter the real slots to the front of a (cap,)-sized buffer.

    The tile programs emit mostly-sentinel slot arrays (one slot per tile
    cell); sorting those directly is O(total cells log cells) — compacting
    first makes the dedup sort run on ~m candidates instead.  ``out_lo`` /
    ``out_hi`` are sentinel-filled buffers whose size bounds the real count.
    """
    valid = lo != _SENTINEL
    dst = jnp.where(valid, jnp.cumsum(valid) - 1, out_lo.shape[0])
    return (
        out_lo.at[dst].set(lo, mode="drop"),
        out_hi.at[dst].set(hi, mode="drop"),
    )


def sbcn_candidates(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
    *,
    tile_elems: int = _TILE_ELEMS,
    pair_cap: int = _PAIR_ELEM_CAP,
    row_chunk: int = _ROW_CHUNK,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """All SBCN candidate edges across WSPD pairs, device-resident.

    The (start, len) pair ranges index the fair-split tree's ``perm`` array;
    all bucketing/padding is vectorized numpy control-plane work (no per-pair
    Python, no device sync).  Returns ``(lo, hi, keep)`` jax int32/bool
    arrays: padded candidate slots sorted by (lo, hi) with ``keep`` marking
    the unique real edges — downstream stages mask instead of compacting, so
    nothing crosses back to the host here.
    """
    perm = perm.astype(np.int64)

    # canonicalize |A| <= |B|
    swap = a_len > b_len
    a_start, b_start = np.where(swap, b_start, a_start), np.where(swap, a_start, b_start)
    a_len, b_len = np.where(swap, b_len, a_len), np.where(swap, a_len, b_len)

    los: list[jax.Array] = []
    his: list[jax.Array] = []

    # fast path: singleton-singleton pairs ARE their own SBCN edge
    ss = (a_len == 1) & (b_len == 1)
    if ss.any():
        pa = perm[a_start[ss]].astype(np.int32)
        pb = perm[b_start[ss]].astype(np.int32)
        los.append(jnp.asarray(np.minimum(pa, pb)))
        his.append(jnp.asarray(np.maximum(pa, pb)))

    rest = np.nonzero(~ss)[0]
    if len(rest):
        al, bl = a_len[rest], b_len[rest]
        # quantize pair sizes to pow2 tiers: with |A| <= |B| canonicalized
        # this is ~30 compiled tile programs, and padded tile area stays
        # within ~20% of the intrinsic sum(|A|*|B|) — coarser tiers (e.g.
        # {1,8,64,512}) compile fewer programs but inflate the slot arrays
        # (and every downstream compaction) by ~4x.
        tiers = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256, 512], np.int64)

        def tier_of(v):
            return tiers[np.searchsorted(tiers, np.minimum(v, tiers[-1]))]

        ka = tier_of(al)
        kb = tier_of(bl)
        big = (al > tiers[-1]) | (bl > tiers[-1]) | (ka * kb > pair_cap)

        for key in np.unique(ka[~big] * (1 << 32) + kb[~big]):
            kaa, kbb = int(key >> 32), int(key & ((1 << 32) - 1))
            sel = rest[(ka == kaa) & (kb == kbb) & ~big]
            P = len(sel)
            # vectorized padded gather of pair point-sets
            ar = a_start[sel][:, None] + np.arange(kaa)[None, :]
            av = (np.arange(kaa)[None, :] < a_len[sel][:, None])
            a_pad = np.where(av, perm[np.minimum(ar, len(perm) - 1)], -1).astype(np.int32)
            br = b_start[sel][:, None] + np.arange(kbb)[None, :]
            bv = (np.arange(kbb)[None, :] < b_len[sel][:, None])
            b_pad = np.where(bv, perm[np.minimum(br, len(perm) - 1)], -1).astype(np.int32)

            # chunk shape: bounded by the tile budget AND by the tier's actual
            # pair count rounded to a power of two — padding a small tier up
            # to the full tile budget would burn orders of magnitude more
            # compute than the real pairs.  Pow2 rounding keeps the jit cache
            # at ~1 program per tier per dataset scale.
            chunk = max(1, min(tile_elems // (kaa * kbb), 1 << (P - 1).bit_length()))
            P_pad = -(-P // chunk) * chunk
            if P_pad != P:
                a_pad = np.concatenate([a_pad, np.full((P_pad - P, kaa), -1, np.int32)])
                b_pad = np.concatenate([b_pad, np.full((P_pad - P, kbb), -1, np.int32)])
            for c0 in range(0, P_pad, chunk):
                lo_c, hi_c = _sbcn_tier_chunk(
                    x,
                    cd2_kmax,
                    jnp.asarray(a_pad[c0 : c0 + chunk]),
                    jnp.asarray(b_pad[c0 : c0 + chunk]),
                )
                los.append(lo_c)
                his.append(hi_c)

        for gi in np.nonzero(big)[0]:
            sel = rest[gi]
            a = perm[a_start[sel] : a_start[sel] + a_len[sel]].astype(np.int32)
            b = perm[b_start[sel] : b_start[sel] + b_len[sel]].astype(np.int32)
            aj, bj = jnp.asarray(a), jnp.asarray(b)
            mutual = _sbcn_large(x, cd2_kmax, aj, bj, row_chunk=row_chunk)
            ga = jnp.broadcast_to(aj[:, None], mutual.shape)
            gb = jnp.broadcast_to(bj[None, :], mutual.shape)
            los.append(jnp.where(mutual, jnp.minimum(ga, gb), _SENTINEL).reshape(-1))
            his.append(jnp.where(mutual, jnp.maximum(ga, gb), _SENTINEL).reshape(-1))

    if not los:
        z = jnp.zeros((0,), jnp.int32)
        return z, z, jnp.zeros((0,), bool)
    lo_all = jnp.concatenate(los)
    hi_all = jnp.concatenate(his)
    # ONE scalar sync sizes the compaction buffer (the only host round-trip
    # in candidate generation); everything else stays device-resident.
    from .. import engine

    n_real = int(engine.to_host(_count_real(lo_all), "candidate_slots"))
    if n_real == 0:
        z = jnp.zeros((0,), jnp.int32)
        return z, z, jnp.zeros((0,), bool)
    cap = -(-n_real // 4096) * 4096  # quantized: reuses the sort/dedup programs
    buf = jnp.full((cap,), _SENTINEL, jnp.int32)
    lo_c, hi_c = _compact_slots(lo_all, hi_all, buf, buf)
    return _dedup_sorted(lo_c, hi_c)


def sbcn_edges(
    x: jax.Array,
    cd2_kmax: jax.Array,
    perm: np.ndarray,
    a_start: np.ndarray,
    a_len: np.ndarray,
    b_start: np.ndarray,
    b_len: np.ndarray,
) -> np.ndarray:
    """Host-compacted SBCN edges: (m, 2) int64, a < b, unique.

    One materialization of the device candidate set (the pipeline proper
    stays on ``sbcn_candidates`` and defers this to the graph compaction).
    """
    from .. import engine

    lo, hi, keep = sbcn_candidates(
        x, cd2_kmax, perm, a_start, a_len, b_start, b_len
    )
    lo, hi, keep = engine.to_host((lo, hi, keep), "candidates")
    return np.stack([lo[keep].astype(np.int64), hi[keep].astype(np.int64)], axis=1)
