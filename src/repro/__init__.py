"""repro: multi-density clustering hierarchies (RNG-HDBSCAN*) at pod scale."""

__version__ = "1.0.0"
