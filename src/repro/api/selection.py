"""`SelectionPolicy`: how clusters are cut out of a condensed tree.

The fitted state (one shared graph, R mutual-reachability MSTs) is
selection-agnostic — excess-of-mass vs leaf selection, the epsilon
threshold of Malzer & Baum's hybrid method, ``allow_single_cluster``, and
``min_cluster_size`` only shape the *view* extracted from it.  This module
gives that family of knobs one frozen, hashable home so a policy can flow
uniformly through ``core.hierarchy`` extraction, ``FittedModel.select``,
``approximate_predict``, and per-request serve options, and so (mpts,
policy) pairs can key extraction caches.
"""

from __future__ import annotations

import dataclasses
import math

SELECTION_METHODS = ("eom", "leaf")


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """Frozen per-query cluster-selection configuration.

    Parameters
    ----------
    method : {"eom", "leaf"}
        Excess-of-mass (FOSC, the HDBSCAN* default) or condensed-tree
        leaves (many fine-grained clusters).
    epsilon : float
        Malzer & Baum's hybrid threshold (*A Hybrid Approach To
        Hierarchical Density-based Cluster Selection*): selected clusters
        born below this distance are merged upward into their first
        ancestor born at a distance >= epsilon, suppressing micro-clusters
        without giving up the hierarchy.  ``0.0`` (default) disables it.
    allow_single_cluster : bool
        Permit the condensed-tree root as a selected cluster.
    min_cluster_size : int, optional
        Condensation threshold.  ``None`` keeps the per-mpts default
        ``max(2, mpts)``.
    """

    method: str = "eom"
    epsilon: float = 0.0
    allow_single_cluster: bool = False
    min_cluster_size: int | None = None

    def __post_init__(self):
        if self.method not in SELECTION_METHODS:
            raise ValueError(
                f"method must be one of {SELECTION_METHODS}; got {self.method!r}"
            )
        eps = float(self.epsilon)
        if not (math.isfinite(eps) and eps >= 0.0):
            raise ValueError(
                f"epsilon must be a finite float >= 0; got {self.epsilon!r}"
            )
        object.__setattr__(self, "epsilon", eps)
        if self.min_cluster_size is not None and self.min_cluster_size < 2:
            raise ValueError(
                f"min_cluster_size must be >= 2 (or None for the per-mpts "
                f"default max(2, mpts)); got {self.min_cluster_size}"
            )

    def replace(self, **changes) -> "SelectionPolicy":
        """A copy with the given fields changed (re-validated)."""
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> dict:
        """JSON-serializable form (artifact headers)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SelectionPolicy":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def describe(self) -> str:
        parts = [self.method]
        if self.epsilon > 0.0:
            parts.append(f"eps={self.epsilon:g}")
        if self.allow_single_cluster:
            parts.append("single-ok")
        if self.min_cluster_size is not None:
            parts.append(f"mcs={self.min_cluster_size}")
        return "+".join(parts)
