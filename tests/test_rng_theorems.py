"""Machine-checks of the paper's theorems.

  * exact RNG == naive O(n^3) oracle (Def. 1)
  * Thm 2: RNG^i subseteq RNG^kmax for i < kmax (oracle-level)
  * Cor. 1: per-mpts MST weight multisets from RNG^kmax == complete graph's
    (MST weight multiset is unique for a graph => correct even under ties)
  * RNG containment chain: rng subseteq rng_star subseteq rng_ss

(Property-based metric checks — mrd symmetry/triangle inequality, core
distance monotonicity — live in test_rng_property.py and need hypothesis.)
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.core import mrd as mrd_mod
from repro.core import multi, ref as oref
from repro.core import rng as rng_mod


def test_exact_rng_matches_naive_oracle(blobs):
    x, _ = blobs
    kmax = 12
    knn_d2, knn_idx = kernels.ops.knn(jnp.asarray(x), kmax - 1)
    g = rng_mod.build_rng_graph(jnp.asarray(x), knn_d2, knn_idx, variant="rng")
    cd = oref.core_distances(x.astype(np.float64), kmax)
    adj = oref.rng_naive(oref.mrd_matrix(x.astype(np.float64), kmax, cd))
    ref_set = set(zip(*map(lambda v: v.tolist(), np.nonzero(np.triu(adj)))))
    ours = set(map(tuple, g.edges.tolist()))
    assert ref_set - ours == set(), f"missing {len(ref_set - ours)} RNG edges"
    # numerically-boundary extra edges are allowed but must be rare
    assert len(ours - ref_set) <= max(2, len(ref_set) // 100)


def test_theorem2_rng_nesting(blobs):
    x, _ = blobs
    x64 = x.astype(np.float64)[:120]
    kmax = 10
    cd = oref.core_distances(x64, kmax)
    prev = None
    for i in (2, 5, kmax):
        adj = oref.rng_naive(oref.mrd_matrix(x64, i, cd))
        edges = set(zip(*map(lambda v: v.tolist(), np.nonzero(np.triu(adj)))))
        if prev is not None:
            assert prev <= edges, f"RNG^{i} does not contain smaller-mpts RNG"
        prev = edges


@pytest.mark.parametrize("variant", ["rng_ss", "rng_star", "rng"])
def test_corollary1_mst_equivalence(blobs, variant):
    """MSTs from the reweighted RNG == MSTs of the complete mrd graph."""
    x, _ = blobs
    kmax = 12
    res = multi.multi_hdbscan(x, kmax, variant=variant)
    cd = oref.core_distances(x.astype(np.float64), kmax)
    for h in res.hierarchies[::4]:
        want = oref.mst_weights(oref.mrd_matrix(x.astype(np.float64), h.mpts, cd))
        np.testing.assert_allclose(np.sort(h.mst_w), want, rtol=1e-5, atol=1e-6)


def test_variant_containment(blobs):
    x, _ = blobs
    kmax = 12
    knn_d2, knn_idx = kernels.ops.knn(jnp.asarray(x), kmax - 1)
    sets = {}
    for v in ("rng_ss", "rng_star", "rng"):
        g = rng_mod.build_rng_graph(jnp.asarray(x), knn_d2, knn_idx, variant=v)
        sets[v] = set(map(tuple, g.edges.tolist()))
    assert sets["rng"] <= sets["rng_star"] <= sets["rng_ss"]


def test_reweight_all_mpts_matches_definition(gauss16d):
    x = jnp.asarray(gauss16d[:200])
    kmax = 8
    knn_d2, knn_idx = kernels.ops.knn(x, kmax - 1)
    cd2 = mrd_mod.core_distances2(knn_d2)
    ea = jnp.asarray([0, 5, 10], jnp.int32)
    eb = jnp.asarray([1, 6, 11], jnp.int32)
    d2e = mrd_mod.edge_d2(x, ea, eb)
    w = np.asarray(mrd_mod.reweight_all_mpts(d2e, cd2, ea, eb))
    for j in range(1, kmax + 1):
        exp = np.maximum(
            np.maximum(np.asarray(cd2)[np.asarray(ea), j - 1],
                       np.asarray(cd2)[np.asarray(eb), j - 1]),
            np.asarray(d2e),
        )
        np.testing.assert_allclose(w[j - 1], exp, rtol=1e-6)
