"""Paper Table II / Fig 7 harness: runtime vs kmax for all methods + the
ratio of computing kmax hierarchies to computing ONE.

  PYTHONPATH=src python examples/multi_density_explore.py [--full]
"""

import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.paper_sweeps import kmax_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger sweep")
    args = ap.parse_args()
    kmaxes = (2, 4, 8, 16, 32, 64, 128) if args.full else (4, 8, 16, 32)
    n = 8000 if args.full else 3000

    rows = kmax_sweep(kmaxes=kmaxes, n=n, d=8)
    print(f"\n{'kmax':>5} {'method':>10} {'wall_s':>8} {'edges':>10} {'ratio_vs_one':>12}")
    for r in rows:
        print(f"{r['kmax']:>5} {r['method']:>10} {r['wall_s']:>8.2f} "
              f"{r['edges']:>10,} {r.get('ratio_vs_one', float('nan')):>12}")
    print("\n(paper Table II: baseline grows linearly in kmax; RNG* stays ~flat;")
    print(" paper Fig 7: RNG* ratio ~2 at kmax=128 — same shape here.)")


if __name__ == "__main__":
    main()
