"""Production mesh construction (function, NOT module-level constant — the
dry-run sets XLA device-count flags before first jax init, and importing this
module must never touch jax device state)."""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: axis_types only where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod single-pod, or 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests / smoke runs)."""
    n = len(jax.devices())
    data = n // model_axis
    return make_mesh_compat((data, model_axis), ("data", "model"))
