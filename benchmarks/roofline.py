"""§Roofline report generator: reads dry-run artifacts, adds analytic
MODEL_FLOPS, emits the per-(arch x shape x mesh) markdown table."""

from __future__ import annotations

import glob
import json
import os

import jax

from repro.configs import SHAPES, get_config
from repro.models import abstract_init

from . import hlo_utils


def param_counts(cfg) -> tuple[float, float]:
    """(total_params, active_params). Active scales routed experts by top_k/E."""
    shapes, specs = abstract_init(cfg)
    flat_s = jax.tree.leaves(shapes)
    flat_spec = jax.tree.leaves(
        specs,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(e, (str, type(None))) for e in v),
    )
    total = active = 0.0
    for sds, spec in zip(flat_s, flat_spec):
        n = 1
        for s in sds.shape:
            n *= s
        total += n
        if cfg.n_experts and "experts" in spec:
            active += n * (cfg.top_k / cfg.n_experts)
        else:
            active += n
    return total, active


def model_flops_per_chip(cfg, shape_name: str, n_chips: int) -> float:
    sh = SHAPES[shape_name]
    total, active = param_counts(cfg)
    # exclude embedding table from the 6ND convention
    emb = cfg.padded_vocab * cfg.d_model
    n_eff = active - emb
    if sh["kind"] == "train":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 6.0 * n_eff * tokens / n_chips
    if sh["kind"] == "prefill":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 2.0 * n_eff * tokens / n_chips
    tokens = sh["global_batch"]  # decode: one token per sequence
    return 2.0 * n_eff * tokens / n_chips


def load_records(art_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "HLO GFLOP/chip | MODEL/HLO | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh or r.get("tag"):
            continue
        cfg = get_config(r["arch"])
        n_chips = 512 if mesh == "multi" else 256
        mf = model_flops_per_chip(cfg, r["shape"], n_chips)
        hlo_f = r["hlo_stats"]["flops_per_device"]
        t = r["roofline"]
        lines.append(
            "| {arch} | {shape} | {tc:.1f} ms | {tm:.1f} ms | {tl:.1f} ms | {dom} | "
            "{gf:.0f} | {ratio:.2f} | {mem:.1f} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=t["t_compute_s"] * 1e3, tm=t["t_memory_s"] * 1e3,
                tl=t["t_collective_s"] * 1e3, dom=t["dominant"],
                gf=hlo_f / 1e9,
                ratio=(mf / hlo_f) if hlo_f else float("nan"),
                mem=r["memory"]["temp_bytes_per_device"] / 2**30,
            )
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_records(args.art)
    print(render_table(recs, args.mesh))


if __name__ == "__main__":
    main()
