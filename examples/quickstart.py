"""Quickstart: one hundred hierarchies for the cost of ~two (paper headline).

Builds a clustered dataset, fits the `MultiHDBSCAN` estimator once, compares
against the optimized rerun baseline, and verifies the hierarchies agree.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import numpy as np

from repro.api import MultiHDBSCAN
from repro.core import multi


def main():
    rng = np.random.default_rng(0)
    centers = rng.uniform(-10, 10, size=(8, 8))
    x = np.concatenate(
        [rng.normal(c, 1.0, size=(500, 8)) for c in centers]
    ).astype(np.float32)
    kmax = 32
    print(f"dataset: n={len(x)}, d={x.shape[1]}, mpts range [2, {kmax}]")

    t0 = time.monotonic()
    est = MultiHDBSCAN(kmax=kmax).fit(x)
    profile = est.mpts_profile()  # forces extraction of the whole range
    t_multi = time.monotonic() - t0
    print(f"\nMultiHDBSCAN: {len(profile)} hierarchies in {t_multi:.2f}s")
    print(f"  graph edges: {est.n_graph_edges_:,} "
          f"(complete graph: {len(x)*(len(x)-1)//2:,})")
    print("  fit timings:", {k: round(v, 2) for k, v in est.timings_.items()})

    t0 = time.monotonic()
    base, _ = multi.hdbscan_baseline(x, [kmax])
    t_one = time.monotonic() - t0
    print(f"\nbaseline, ONE hierarchy (mpts={kmax}): {t_one:.2f}s")
    print(f"=> {len(profile)} hierarchies for "
          f"{t_multi / t_one:.1f}x the cost of one (paper: ~2x at kmax=128)")

    _, _, w = est.mst_for(kmax)
    np.testing.assert_allclose(
        np.sort(w), np.sort(base[0].mst_w), rtol=1e-5, atol=1e-6
    )
    print("\nMST weight multisets agree with the baseline — hierarchies are exact.")

    print("\nclusters per mpts (sampled):")
    for row in profile[:: max(1, len(profile) // 8)]:
        print(f"  mpts={row['mpts']:3d}: {row['n_clusters']:3d} clusters, "
              f"{row['n_noise']:4d} noise pts")


if __name__ == "__main__":
    main()
