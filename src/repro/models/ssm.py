"""Mamba-2 (SSD, state-space duality) — attention-free LM.

Chunked SSD algorithm (Dao & Gu 2024, §6): within a chunk the token mixing is
the quadratic "attention-like" masked form (MXU-friendly (Q x Q) tiles); chunk
states propagate through a tiny sequential scan of (H, N, P) tensors.  Exactly
the blocked structure a TPU wants: all heavy math is batched einsums, the
recurrence is O(S / chunk) scan steps.

Decode carries (conv_state, ssm_state) — O(1) in sequence length, which is why
the long_500k cell runs for this arch.

Correctness oracle: tests/test_models_smoke.py checks the chunked form against
the naive per-token recurrence on small shapes.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers as L


def init(cfg, key) -> tuple[dict, dict]:
    ks = iter(jax.random.split(key, 8))
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.d_state
    h = cfg.n_ssm_heads
    conv_dim = di + 2 * n
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = L.dense_init(
        next(ks), (cfg.padded_vocab, d), ("vocab", "embed"), jnp.float32, scale=0.02
    )
    p["final_norm"], s["final_norm"] = L.rmsnorm_init(d)

    def layer_init(k):
        kk = jax.random.split(k, 4)
        lp, ls = {}, {}
        lp["ln"], ls["ln"] = L.rmsnorm_init(d)
        # in_proj -> [z (di), xBC (di + 2n), dt (h)]
        lp["in_proj"], ls["in_proj"] = L.dense_init(
            kk[0], (d, 2 * di + 2 * n + h), ("embed", "inner_all"), jnp.float32
        )
        lp["conv_w"], ls["conv_w"] = (
            jax.random.normal(kk[1], (cfg.d_conv, conv_dim), jnp.float32) * 0.2,
            ("conv", "inner"),
        )
        lp["conv_b"], ls["conv_b"] = jnp.zeros((conv_dim,), jnp.float32), ("inner",)
        lp["a_log"], ls["a_log"] = (
            jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
            ("ssm_heads",),
        )
        lp["d_skip"], ls["d_skip"] = jnp.ones((h,), jnp.float32), ("ssm_heads",)
        lp["dt_bias"], ls["dt_bias"] = jnp.zeros((h,), jnp.float32), ("ssm_heads",)
        lp["norm"], ls["norm"] = jnp.zeros((di,), jnp.float32), ("inner",)
        lp["out_proj"], ls["out_proj"] = L.dense_init(
            kk[2], (di, d), ("inner", "embed"), jnp.float32
        )
        return lp, ls

    base = next(ks)
    outs = [layer_init(jax.random.fold_in(base, i)) for i in range(cfg.n_layers)]
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
    p_specs = jax.tree.map(
        lambda sp: ("layers",) + sp,
        outs[0][1],
        is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, str) for e in v),
    )
    s["layers"] = p_specs
    return p, s


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv over seq. xbc: (B,S,C), w: (K,C). state: (B,K-1,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i].astype(xbc.dtype) for i in range(k)
    )
    out = out + b.astype(xbc.dtype)
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, b_in, c_in, dt, a_log, chunk: int):
    """Chunked SSD. x: (B,S,H,P); b_in/c_in: (B,S,N); dt: (B,S,H) (softplus'd).

    Returns y: (B,S,H,P). ngroups=1 (B/C shared across heads).
    """
    bsz, s_len, h, p_dim = x.shape
    n = b_in.shape[-1]
    q = chunk
    nc = s_len // q
    a = -jnp.exp(a_log.astype(jnp.float32))                      # (H,)
    da = dt.astype(jnp.float32) * a                              # (B,S,H)

    xc = x.reshape(bsz, nc, q, h, p_dim)
    bc = b_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    dac = da.reshape(bsz, nc, q, h)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=2)                                # (B,C,Q,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,C,Qi,Qj,H)
    iq = jnp.arange(q)
    causal = iq[:, None] >= iq[None, :]
    cmask = causal[None, None, :, :, None]
    # mask BEFORE exp: anti-causal entries have seg >> 0, exp overflows, and
    # `where` does not stop the inf from poisoning the BACKWARD pass
    decay = jnp.where(cmask, jnp.exp(jnp.where(cmask, seg, 0.0)), 0.0)

    # within-chunk ("diagonal") term
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)               # (B,C,Qi,Qj)
    xdt = xc.astype(jnp.float32) * dtc[..., None]                # (B,C,Q,H,P)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, xdt)

    # chunk-final states: S_c = sum_j exp(cum_last - cum_j) B_j (x_j dt_j)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,C,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, decay_out, xdt)

    # inter-chunk recurrence over nc steps
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,C,H)

    def scan_fn(s_prev, inp):
        st, dec = inp                                            # (B,H,N,P), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, p_dim), jnp.float32)
    _, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                   # (B,C,H,N,P)

    # off-chunk ("low-rank") term: y_off_i = C_i . (exp(cum_i) * S_prev)
    decay_in = jnp.exp(cum)                                      # (B,C,Q,H)
    y_off = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, decay_in, s_prevs)

    y = (y_diag + y_off).reshape(bsz, s_len, h, p_dim)
    return y


def _mixer(pl, h_in, cfg, conv_state=None, ssm_state=None, single_step=False):
    """The Mamba2 mixer. Returns (y, new_conv_state, new_ssm_state)."""
    dt_model = h_in.dtype
    di, n, nh, pdim = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_head
    proj = constrain(
        h_in @ pl["in_proj"].astype(dt_model), ("act_batch", "act_seq", "act_ff")
    )
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, new_conv = _causal_conv(xbc, pl["conv_w"], pl["conv_b"], conv_state)
    x = xbc[..., :di]
    b_in = xbc[..., di : di + n]
    c_in = xbc[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])
    bsz, s_len, _ = x.shape
    xh = x.reshape(bsz, s_len, nh, pdim)

    if single_step:
        a = -jnp.exp(pl["a_log"].astype(jnp.float32))
        dec = jnp.exp(dt[:, 0, :] * a)                           # (B,H)
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]   # (B,H,P)
        s_new = ssm_state * dec[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", b_in[:, 0].astype(jnp.float32), xdt
        )
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(jnp.float32), s_new)
        y = y + pl["d_skip"][:, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(bsz, 1, di)
        new_ssm = s_new
    else:
        pad = (-s_len) % cfg.ssd_chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
            c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y = ssd_chunked(xh, b_in, c_in, dt, pl["a_log"], cfg.ssd_chunk)
        y = y[:, :s_len] + pl["d_skip"][:, None] * xh[:, :s_len].astype(jnp.float32)
        y = y.reshape(bsz, s_len, di)
        new_ssm = None

    y = L.rmsnorm(y.astype(dt_model) * jax.nn.silu(z), pl["norm"])
    return y @ pl["out_proj"].astype(dt_model), new_conv, new_ssm


def forward(p, cfg, tokens, patch_embeds=None):
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[tokens]

    def body(carry, pl):
        x, aux = carry
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = L.rmsnorm(x, pl["ln"])
        y, _, _ = _mixer(pl, h, cfg)
        return (x + y, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), p["layers"])
    x = L.rmsnorm(x, p["final_norm"])
    return x, jnp.float32(0.0)


def logits_fn(p, cfg, x):
    return x @ p["embed"].astype(x.dtype).T


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    del max_len  # O(1) state
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, cfg.n_ssm_heads, cfg.d_state, cfg.ssm_head),
            jnp.float32,
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(p, cfg, cache, cur_tokens):
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[cur_tokens]

    def body(carry, pl):
        x, cache, li = carry
        h = L.rmsnorm(x, pl["ln"])
        y, conv_new, ssm_new = _mixer(
            pl, h, cfg, conv_state=cache["conv"][li], ssm_state=cache["ssm"][li],
            single_step=True,
        )
        cache = dict(
            cache,
            conv=jax.lax.dynamic_update_index_in_dim(
                cache["conv"], conv_new.astype(cache["conv"].dtype), li, 0),
            ssm=jax.lax.dynamic_update_index_in_dim(cache["ssm"], ssm_new, li, 0),
        )
        return (x + y, cache, li + 1), None

    (x, cache, _), _ = jax.lax.scan(body, (x, cache, jnp.int32(0)), p["layers"])
    x = L.rmsnorm(x, p["final_norm"])
    logits = logits_fn(p, cfg, x)
    return logits[:, 0], dict(cache, pos=cache["pos"] + 1)


def prefill(p, cfg, tokens, max_len: int, patch_embeds=None, cache_dtype=jnp.bfloat16):
    """Prefill by running the chunked forward, then recomputing final states.

    For the SSD arch the 'cache' is the O(1) (conv, ssm) state after the
    prompt; we obtain it by a single forward pass that also returns states.
    """
    dt = jnp.dtype(cfg.dtype)
    x = p["embed"].astype(dt)[tokens]
    bsz, s_len = tokens.shape

    def body(carry, pl):
        x, _ = carry
        h = L.rmsnorm(x, pl["ln"])
        # full mixer + state extraction via one extra single-step-free pass:
        dt_model = h.dtype
        di, n, nh, pdim = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_head
        proj = h @ pl["in_proj"].astype(dt_model)
        z, xbc, dt_raw = _split_proj(cfg, proj)
        xbc_c, conv_fin = _causal_conv(xbc, pl["conv_w"], pl["conv_b"])
        xs = xbc_c[..., :di]
        b_in = xbc_c[..., di : di + n]
        c_in = xbc_c[..., di + n :]
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])
        xh = xs.reshape(bsz, s_len, nh, pdim)
        pad = (-s_len) % cfg.ssd_chunk
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_p = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_p = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        y = ssd_chunked(xh_p, b_p, c_p, dt_p, pl["a_log"], cfg.ssd_chunk)
        y = y[:, :s_len] + pl["d_skip"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, s_len, di)
        y = L.rmsnorm(y.astype(dt_model) * jax.nn.silu(z), pl["norm"])
        y = y @ pl["out_proj"].astype(dt_model)
        # final ssm state: recurrence once more over all tokens (cheap einsum
        # form: state = sum_j decay(j..S) dt_j B_j x_j)
        a = -jnp.exp(pl["a_log"].astype(jnp.float32))
        da = dtv * a
        rev_cum = jnp.cumsum(da[:, ::-1, :], axis=1)[:, ::-1, :] - da  # sum_{k>j} da_k
        decay_to_end = jnp.exp(rev_cum + da)                            # include own dt? no:
        decay_to_end = jnp.exp(rev_cum)                                 # exp(sum_{k>j} da_k)
        xdt = xh.astype(jnp.float32) * dtv[..., None]
        ssm_fin = jnp.einsum("bjn,bjh,bjhp->bhnp", b_in.astype(jnp.float32), decay_to_end, xdt)
        return (x + y, None), (conv_fin.astype(cache_dtype), ssm_fin)

    (x, _), (convs, ssms) = jax.lax.scan(body, (x, None), p["layers"])
    x = L.rmsnorm(x, p["final_norm"])
    logits = logits_fn(p, cfg, x[:, -1:])
    cache = {"conv": convs, "ssm": ssms, "pos": jnp.int32(s_len)}
    return logits[:, 0], cache
