"""Docs sanity check: README python blocks must parse, and the ones that
exercise the public API must actually run.

Every ```python fenced block in README.md is compiled; blocks that import
only from the public surface (repro, numpy) are executed in a shared
namespace so the quickstart is guaranteed to work as printed.

  PYTHONPATH=src python tools/check_readme.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def blocks(md: str) -> list[str]:
    return re.findall(r"```python\n(.*?)```", md, flags=re.DOTALL)


def main() -> int:
    md = (ROOT / "README.md").read_text()
    found = blocks(md)
    if not found:
        print("FAIL: README.md has no ```python blocks")
        return 1

    ns: dict = {}
    n_run = 0
    for i, src in enumerate(found):
        try:
            code = compile(src, f"README.md[block {i}]", "exec")
        except SyntaxError as e:
            print(f"FAIL: README block {i} does not parse: {e}")
            return 1
        try:
            exec(code, ns)  # noqa: S102 - the point is to run the docs
            n_run += 1
        except Exception as e:  # noqa: BLE001
            print(f"FAIL: README block {i} raised {type(e).__name__}: {e}")
            return 1

    import repro
    import repro.api  # noqa: F401  (public surface must import)

    print(f"ok: {len(found)} README blocks parsed, {n_run} executed; "
          f"repro {repro.__version__} imports")
    return 0


if __name__ == "__main__":
    sys.exit(main())
