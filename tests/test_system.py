"""End-to-end behaviour tests: the paper's headline claims, DBCV selection,
serving engine, and the multi-mpts <-> baseline agreement at system level."""

import numpy as np
import pytest

from repro.core import dbcv, multi
from repro.train import data as data_lib


def test_many_hierarchies_agree_with_baseline(blobs):
    """The full multi-hierarchy output == per-mpts baseline output (labels),
    i.e. the system produces the SAME hierarchies the naive rerun would."""
    x, _ = blobs
    kmax = 10
    res = multi.multi_hdbscan(x, kmax, variant="rng_star")
    base, _ = multi.hdbscan_baseline(x, [3, 6, 10])
    by_mpts = {h.mpts: h for h in res.hierarchies}
    for hb in base:
        h = by_mpts[hb.mpts]
        np.testing.assert_allclose(
            np.sort(h.mst_w), np.sort(hb.mst_w), rtol=1e-5, atol=1e-6
        )
        # partitions match up to label permutation and tie-boundary points:
        # mrd ties make the binary dendrogram order (hence a few boundary
        # memberships) implementation-dependent even for identical MST weights
        assert abs(h.n_clusters - hb.n_clusters) <= 1
        agree = 0
        total = 0
        for c in range(h.n_clusters):
            members = hb.labels[h.labels == c]
            members = members[members >= 0]
            if len(members) == 0:
                continue
            vals, counts = np.unique(members, return_counts=True)
            agree += counts.max()
            total += counts.sum()
        assert agree / max(total, 1) > 0.95


def test_dbcv_prefers_good_clustering(blobs):
    x, gt = blobs
    res = multi.multi_hdbscan(x, 8, variant="rng_star")
    h = [hh for hh in res.hierarchies if hh.mpts == 6][0]
    good = dbcv.dbcv_relative_validity(h.mst_ea, h.mst_eb, h.mst_w, h.labels)
    rng = np.random.default_rng(0)
    rand_labels = rng.integers(0, 3, size=len(x))
    bad = dbcv.dbcv_relative_validity(h.mst_ea, h.mst_eb, h.mst_w, rand_labels)
    assert good > bad


def test_dbcv_selects_reasonable_mpts(blobs):
    """Paper §I: DBCV across hierarchies identifies good density levels.
    mpts=2 shatters the blobs; the DBCV argmax should not pick it."""
    x, _ = blobs
    res = multi.multi_hdbscan(x, 10, variant="rng_star")
    scores = {
        h.mpts: dbcv.dbcv_relative_validity(h.mst_ea, h.mst_eb, h.mst_w, h.labels)
        for h in res.hierarchies
    }
    best = max(scores, key=scores.get)
    assert scores[best] >= scores[2], scores  # shattered mpts=2 never wins


def test_serving_engine_generates():
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.lm import Engine, GenRequest

    cfg = get_config("qwen2_1_5b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=64)
    reqs = [
        GenRequest(prompt=np.array([0, 5, 9], np.int32), max_new_tokens=8),
        GenRequest(prompt=np.array([0, 7], np.int32), max_new_tokens=8),
    ]
    outs = eng.generate(reqs)
    assert len(outs) == 2
    assert all(1 <= len(o) <= 8 for o in outs)
    assert eng.last_stats["tok_per_s"] > 0


@pytest.mark.slow
def test_embedding_stream_clusters():
    """data_lib's synthetic embedding stream has recoverable structure."""
    x = data_lib.embedding_stream(seed=1, n=600, dim=8, n_modes=5)
    res = multi.multi_hdbscan(x, 8, variant="rng_star")
    h = [hh for hh in res.hierarchies if hh.mpts == 8][0]
    assert 3 <= h.n_clusters <= 8
