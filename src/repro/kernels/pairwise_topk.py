"""Pallas TPU kernel: tiled pairwise squared distances + streaming top-k.

This is the compute hot-spot of the paper's pipeline: the single ``kmax``-NN
pass that yields *all* core distances ``c_j, j <= kmax`` at once (paper §IV,
Algorithm 1 lines 1-3).  The paper uses a Kd-tree on CPU; the TPU-native
adaptation is a dense blocked computation on the MXU:

    d2(q, k) = ||q||^2 + ||k||^2 - 2 <q, k>

with a flash-attention-style *streaming* top-k merge over key tiles, so the
(n x n) distance matrix is never materialized.  The working set per grid step
is one (bq, d) query tile, one (bk, d) key tile and the (bq, K) running top-k
state, all resident in VMEM.

Grid layout: ``(n_q_tiles, n_k_tiles)`` with the key-tile axis declared
"arbitrary" (sequential) so the output block — whose index map ignores the key
axis — is revisited and acts as an accumulator.

Notes on TPU lowering: the merge uses ``jax.lax.top_k`` / ``sort`` which lower
on TPU for the trailing lane dimension; block shapes are chosen so the sorted
axis (K + bk) stays in-lane.  Validated in ``interpret=True`` mode on CPU
against ``ref.knn_ref`` (see tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .compat import COMPILER_PARAMS as _COMPILER_PARAMS



def _pairwise_topk_kernel(
    q_ref,      # (bq, d)    VMEM: query point tile
    k_ref,      # (bk, d)    VMEM: key point tile
    out_d_ref,  # (bq, K)    VMEM: running top-k squared distances (ascending)
    out_i_ref,  # (bq, K)    VMEM: running top-k global indices
    *,
    block_q: int,
    block_k: int,
    k_top: int,
    n_total: int,
):
    qi = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        out_d_ref[...] = jnp.full((block_q, k_top), jnp.inf, jnp.float32)
        out_i_ref[...] = jnp.full((block_q, k_top), -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)

    # ||q||^2 + ||k||^2 - 2 q.k^T on the MXU.
    qn = jnp.sum(q * q, axis=-1, keepdims=True)            # (bq, 1)
    kn = jnp.sum(k * k, axis=-1, keepdims=True).T          # (1, bk)
    d2 = qn + kn - 2.0 * jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(d2, 0.0)                              # numeric floor

    # Global indices of this key tile; mask self-pairs and padded keys.
    row_g = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    col_g = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    invalid = (col_g == row_g) | (col_g >= n_total)
    d2 = jnp.where(invalid, jnp.inf, d2)

    # Streaming merge: concat running state with the new tile, keep K smallest.
    cat_d = jnp.concatenate([out_d_ref[...], d2], axis=1)              # (bq, K+bk)
    cat_i = jnp.concatenate([out_i_ref[...], col_g], axis=1)
    neg_top, arg_top = jax.lax.top_k(-cat_d, k_top)                    # ascending d2
    out_d_ref[...] = -neg_top
    out_i_ref[...] = jnp.take_along_axis(cat_i, arg_top, axis=1)


def pairwise_topk(
    x: jax.Array,
    k_top: int,
    *,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN of every row of ``x`` against all other rows.

    Returns ``(d2, idx)`` with shapes ``(n, k_top)``: squared distances in
    ascending order (self excluded) and the matching global row indices.
    """
    n, d = x.shape
    if k_top > n - 1:
        raise ValueError(f"k_top={k_top} must be <= n-1={n - 1}")
    block_q = min(block_q, max(8, n))
    block_k = min(block_k, max(8, n))

    n_pad_q = -(-n // block_q) * block_q
    n_pad_k = -(-n // block_k) * block_k
    n_pad = max(n_pad_q, n_pad_k)
    xp = jnp.zeros((n_pad, d), x.dtype).at[:n].set(x)

    grid = (n_pad // block_q, n_pad // block_k)
    kernel = functools.partial(
        _pairwise_topk_kernel,
        block_q=block_q,
        block_k=block_k,
        k_top=k_top,
        n_total=n,
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_top), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k_top), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k_top), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k_top), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, xp)
    return out_d[:n], out_i[:n]
