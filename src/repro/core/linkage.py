"""Batched single-linkage dendrograms on device (extraction, stage 1).

The seed implementation built each dendrogram with a per-edge *Python*
union-find loop (core.hierarchy.single_linkage), run once per mpts value —
interpreter-bound scalar work repeated R times.  Here the whole mpts range
is ONE XLA program: a ``fori_loop`` over the n-1 weight-sorted edges with a
path-halving union-find, vmapped across the R hierarchies.  The loop is
compiled once and executes with no Python in it; the batch dimension keeps
the device busy while each lane runs its (inherently sequential) merges.

Output follows the scipy linkage convention used by ``core.hierarchy``:
cluster ids 0..n-1 are points, ``n + i`` is the cluster born at merge row
``i``; rows are ordered by ascending merge height (stable in the input edge
order, matching the host reference's ``np.lexsort((arange, w))``).

Precondition: every row of ``(ea, eb)`` is a spanning tree of the n points
(exactly n-1 edges, no duplicates/cycles), so every edge merges two distinct
components and no "skip" branch is needed.  ``core.multi`` feeds exact MSTs,
which satisfy this by construction; ``validate_spanning`` is a cheap host
check for external callers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _find(parent, v):
    """Union-find root of v: read-only walk (scalar loop carry).

    No path compression: compression would mutate the (n,) parent array
    inside the while body, and under vmap the loop's lane masking turns each
    iteration into a full-array select — O(n) copies per find.  Union by
    size (see `step` below) bounds the walk at log2(n) instead.
    """

    def cond(u):
        return parent[u] != u

    return jax.lax.while_loop(cond, lambda u: parent[u], v)


def _single_linkage_one(ea, eb, w, n: int):
    """One spanning tree's (n-1) edges -> merge rows (left, right, height, size)."""
    order = jnp.argsort(w)  # jnp.argsort is stable: ties keep input edge order
    ea_s = ea[order].astype(jnp.int32)
    eb_s = eb[order].astype(jnp.int32)
    w_s = w[order]
    n_merges = ea.shape[0]

    def step(i, state):
        parent, label, csize, left, right, size = state
        ra = _find(parent, ea_s[i])
        rb = _find(parent, eb_s[i])
        sz = csize[ra] + csize[rb]
        left = left.at[i].set(label[ra])
        right = right.at[i].set(label[rb])
        size = size.at[i].set(sz)
        # union by size: tree depth stays <= log2(n), keeping finds cheap
        winner = jnp.where(csize[ra] >= csize[rb], ra, rb)
        loser = jnp.where(csize[ra] >= csize[rb], rb, ra)
        parent = parent.at[loser].set(winner)
        label = label.at[winner].set(n + i)
        csize = csize.at[winner].set(sz)
        return parent, label, csize, left, right, size

    state = (
        jnp.arange(n, dtype=jnp.int32),       # union-find parent
        jnp.arange(n, dtype=jnp.int32),       # cluster label of each root
        jnp.ones((n,), jnp.int32),            # component size at each root
        jnp.zeros((n_merges,), jnp.int32),
        jnp.zeros((n_merges,), jnp.int32),
        jnp.zeros((n_merges,), jnp.int32),
    )
    _, _, _, left, right, size = jax.lax.fori_loop(0, n_merges, step, state)
    return left, right, w_s, size


@functools.partial(jax.jit, static_argnames=("n",))
def single_linkage_batch(ea, eb, w, *, n: int):
    """Dendrograms for a batch of spanning trees in one device program.

    Args:
      ea, eb: (R, n-1) integer endpoints; each row a spanning tree over n points.
      w: (R, n-1) non-negative merge weights (real, NOT squared, distances).
      n: number of points (static).
    Returns:
      (left, right, height, size), each (R, n-1): scipy-convention merge rows
      sorted by ascending height.
    """
    one = functools.partial(_single_linkage_one, n=n)
    return jax.vmap(one)(jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(w))


def linkage_to_Z(left, right, height, size) -> np.ndarray:
    """Pack one row's merge arrays into a scipy-style (n-1, 4) float64 Z."""
    return np.stack(
        [
            np.asarray(left, np.float64),
            np.asarray(right, np.float64),
            np.asarray(height, np.float64),
            np.asarray(size, np.float64),
        ],
        axis=-1,
    )


def validate_spanning(ea: np.ndarray, eb: np.ndarray, n: int) -> None:
    """Raise ValueError unless (ea, eb) is a spanning tree of n vertices."""
    ea = np.asarray(ea)
    eb = np.asarray(eb)
    if ea.shape != (n - 1,) or eb.shape != (n - 1,):
        raise ValueError(f"expected {n - 1} edges, got {ea.shape} / {eb.shape}")
    # n-1 edges span n vertices iff the edge set is acyclic & connected; a
    # union-find count suffices and this is a host-side debug path only.
    parent = np.arange(n)

    def find(v):
        while parent[v] != v:
            parent[v] = parent[parent[v]]
            v = parent[v]
        return v

    merges = 0
    for a, b in zip(ea, eb):
        ra, rb = find(a), find(b)
        if ra == rb:
            raise ValueError("edge list contains a cycle")
        parent[ra] = rb
        merges += 1
    if merges != n - 1:
        raise ValueError("edge list does not span")
