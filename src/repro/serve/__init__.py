"""Serving layer.

``engine.ClusterServeEngine`` is the clustering serve surface (the repo's
actual workload): process-resident fitted state — either fit in-process or
booted refit-free from a saved ``FittedModel`` artifact via
``ClusterServeEngine.load(path)`` — micro-batched out-of-sample prediction,
per-request ``SelectionPolicy``, LRU-bounded per-(mpts, policy) extraction.
``lm`` keeps the small batched LM decode engine used by the
accelerator-side smoke tests and examples/serve_lm.py.
"""

from . import engine, lm
from .engine import ClusterServeEngine

__all__ = ["ClusterServeEngine", "engine", "lm"]
